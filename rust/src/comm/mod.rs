//! The communication subsystem: collectives over flat bucket spans with
//! unified byte / round / latency accounting.
//!
//! [`Communicator`] is the engine-facing contract — `all_reduce_mean`,
//! `reduce_scatter_mean`, `all_gather` — and [`SharedMemComm`] is the
//! in-process implementation backing the DDP simulation (standing in for
//! NCCL). Three properties matter to the rest of the engine:
//!
//! * **Tag-matched, order-independent sessions.** Every collective names
//!   a `tag`; ranks join the session for that tag in whatever order their
//!   threads reach it. This is what lets backward-fusion fire a bucket's
//!   reduce from a worker-pool thread *while backward is still running*
//!   (`exec::pool` comm jobs): two ranks may issue bucket 5's and bucket
//!   6's reduces in opposite orders without deadlock. Repeated use of a
//!   tag is sequenced per rank, so step k and step k+1 of the same bucket
//!   never collide.
//! * **Deterministic reduction order.** A mean-reduce sums rank
//!   contributions in rank order (0, 1, …, W−1) and then scales by 1/W,
//!   on *every* rank. All ranks therefore compute bit-identical results
//!   (f32 addition is commutative but not associative — a rank-dependent
//!   order would let replicas drift in the low bits), and a
//!   `reduce_scatter_mean` shard is bit-identical to the corresponding
//!   region of an `all_reduce_mean`. The ZeRO shard stages'
//!   ([`ShardStage`]) bit-exactness guarantee rests on this. The
//!   `_spans` collective variants generalize the ownership partition
//!   beyond the balanced `shard_span` split — the chunked ZeRO path
//!   hands each rank the intersection of its bucket-level shard with
//!   the chunk.
//! * **One accounting path.** Every collective — including the scalar
//!   loss reduce — lands in the same [`CommStats`] (bytes moved, rounds,
//!   blocked nanoseconds), so `DdpReport` totals cannot disagree with
//!   themselves the way the old `AllReducer::bytes_moved` /
//!   `reduces_per_step` split did.
//!
//! Shard spans (which contiguous region of a flat buffer rank r owns)
//! come from [`crate::tensor::flat::shard_span`]; the update-side span
//! arithmetic lives in [`crate::optim::bucket::apply_bucket_update_range`].
//!
//! **Topology axis.** [`SharedMemComm`] is the *flat* algorithm: one
//! staged session per collective, every rank in, every rank out. The
//! [`RingComm`], [`TreeComm`], and [`HierComm`] siblings implement the
//! same trait over genuine hop-by-hop message passing ([`p2p`]) —
//! bandwidth-optimal chunked ring reduce-scatter + all-gather,
//! latency-optimal binomial reduce + broadcast, and the two-tier
//! composition (ring within each node of a [`Topology`], tree across
//! node leaders) — selected through [`CommAlgo`] / `DdpConfig::algo` /
//! `--algo`. All four are bit-identical (the per-origin payloads of
//! [`p2p`] let every algorithm reduce in rank order), and all four land
//! in the same [`CommStats`], with a per-hop `hops` leg counter whose
//! closed forms ([`algo`]) are shared with `memsim`'s interconnect cost
//! model. `--algo auto` ([`AlgoSelect::Auto`]) routes each bucket's
//! tags to the algorithm a memsim-driven plan picked for it ([`plan`]).

pub mod algo;
pub mod hier;
pub mod p2p;
pub mod plan;
pub mod ring;
pub mod tree;

pub use algo::{
    make_comm, make_comm_shared, wire_all_gather, wire_all_gather_spans,
    wire_all_gather_spans_chunked, wire_all_reduce, wire_all_reduce_chunked, wire_reduce_scatter,
    wire_reduce_scatter_spans, wire_reduce_scatter_spans_chunked, AlgoSelect, CommAlgo, Topology,
    WireCost,
};
pub use hier::HierComm;
pub use p2p::ActNet;
pub use plan::{MixedComm, StepPlan, UnitPlan};
pub use ring::RingComm;
pub use tree::TreeComm;

use crate::tensor::flat::shard_partition;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

/// Unified collective accounting, shared by every operation a
/// [`Communicator`] performs.
#[derive(Debug)]
pub struct CommStats {
    /// Total bytes sent + received across all ranks and collectives,
    /// priced at [`CommStats::set_elem_bytes`]'s wire dtype.
    pub bytes: AtomicU64,
    /// Collective calls, counted once per participating rank (so one
    /// all-reduce among W ranks adds W).
    pub rounds: AtomicU64,
    /// Wallclock spent inside collectives (waiting + reducing), summed
    /// across ranks, in nanoseconds.
    pub wait_ns: AtomicU64,
    /// Point-to-point transfer legs, counted at each endpoint: a ring
    /// all-reduce adds `4(W−1)` per rank, a tree all-reduce `4(W−1)`
    /// total, and a flat session 2 per rank (contribute + collect). The
    /// closed forms live in [`algo`] and are what `memsim` prices.
    pub hops: AtomicU64,
    /// Wire bytes per element (4 = f32 — the in-memory representation
    /// every payload actually uses; 2 models BF16 wire traffic). Every
    /// internal byte count is a multiple of 4 per element, so the
    /// rescaling in [`CommStats::record`] stays exact and measured
    /// totals keep matching the dtype-aware closed forms bit-for-bit.
    elem_bytes: AtomicU64,
    /// Point-to-point payload bytes (the pipeline activation exchange),
    /// counted at both endpoints of every message — a separate leg from
    /// the collective `bytes` so collective wire accounting stays exact.
    /// Never rescaled by the wire dtype: activation payloads cross the
    /// boundary as exact f32 words regardless of the arena dtype (the
    /// bit-identity contract of pipelined training).
    pub p2p_bytes: AtomicU64,
    /// Point-to-point messages, counted at each endpoint (one post +
    /// one take per message → 2 per in-flight activation tensor).
    pub p2p_msgs: AtomicU64,
    /// Tensor-parallel activation all-reduce bytes (the [`tags::tp`]
    /// leg): partial-output exchanges between TP ranks of one layer,
    /// counted at both endpoints like the p2p leg. Never rescaled by
    /// the wire dtype — TP partial sums cross as exact f32 words so
    /// the rank-ordered fold stays bit-identical to the unsplit matmul.
    pub tp_bytes: AtomicU64,
    /// Tensor-parallel messages, counted at each endpoint (one post +
    /// one take per delivered partial → 2 per peer per sync point).
    pub tp_msgs: AtomicU64,
}

impl Default for CommStats {
    fn default() -> Self {
        Self {
            bytes: AtomicU64::new(0),
            rounds: AtomicU64::new(0),
            wait_ns: AtomicU64::new(0),
            hops: AtomicU64::new(0),
            elem_bytes: AtomicU64::new(4),
            p2p_bytes: AtomicU64::new(0),
            p2p_msgs: AtomicU64::new(0),
            tp_bytes: AtomicU64::new(0),
            tp_msgs: AtomicU64::new(0),
        }
    }
}

impl CommStats {
    /// Set the wire dtype width this accounting prices payloads at
    /// (4 = f32, 2 = bf16). Call before any collective runs — rescaling
    /// applies per [`CommStats::record`] call, not retroactively.
    pub fn set_elem_bytes(&self, eb: u64) {
        assert!(eb == 2 || eb == 4, "wire elem bytes must be 2 (bf16) or 4 (f32)");
        self.elem_bytes.store(eb, Ordering::Relaxed);
    }

    pub(crate) fn record(&self, sent: usize, received: usize, hops: u64, t0: Instant) {
        let eb = self.elem_bytes.load(Ordering::Relaxed);
        // payload byte counts are f32-sized (4/element); reprice at the
        // wire dtype — exact because every count is a multiple of 4
        self.bytes
            .fetch_add((sent + received) as u64 * eb / 4, Ordering::Relaxed);
        self.rounds.fetch_add(1, Ordering::Relaxed);
        self.hops.fetch_add(hops, Ordering::Relaxed);
        self.wait_ns
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
    }

    /// Record one endpoint of a point-to-point message (`bytes` of
    /// payload). Called once by the sender at post and once by the
    /// receiver at take, so a delivered message contributes `2×bytes`
    /// to [`CommStats::p2p_bytes`] and 2 to [`CommStats::p2p_msgs`] —
    /// the same both-endpoints convention the collective `bytes` leg
    /// uses.
    pub fn record_p2p(&self, bytes: u64) {
        self.p2p_bytes.fetch_add(bytes, Ordering::Relaxed);
        self.p2p_msgs.fetch_add(1, Ordering::Relaxed);
    }

    /// Current `(bytes, messages)` totals of the p2p leg.
    pub fn p2p(&self) -> (u64, u64) {
        (self.p2p_bytes.load(Ordering::Relaxed), self.p2p_msgs.load(Ordering::Relaxed))
    }

    /// Record one endpoint of a tensor-parallel partial-output message.
    /// Same both-endpoints convention as [`CommStats::record_p2p`]: a
    /// delivered partial contributes `2×bytes` to
    /// [`CommStats::tp_bytes`] and 2 to [`CommStats::tp_msgs`].
    pub fn record_tp(&self, bytes: u64) {
        self.tp_bytes.fetch_add(bytes, Ordering::Relaxed);
        self.tp_msgs.fetch_add(1, Ordering::Relaxed);
    }

    /// Current `(bytes, messages)` totals of the tensor-parallel leg.
    pub fn tp(&self) -> (u64, u64) {
        (self.tp_bytes.load(Ordering::Relaxed), self.tp_msgs.load(Ordering::Relaxed))
    }

    /// A point-in-time copy of the counters — an epoch marker. Pair
    /// with [`CommStats::delta_since`] to attribute traffic to a window
    /// (the calibration probes use this to keep their synthetic
    /// collectives out of the reported per-step accounting).
    pub fn snapshot(&self) -> CommStatsSnapshot {
        CommStatsSnapshot {
            bytes: self.bytes.load(Ordering::Relaxed),
            rounds: self.rounds.load(Ordering::Relaxed),
            wait_ns: self.wait_ns.load(Ordering::Relaxed),
            hops: self.hops.load(Ordering::Relaxed),
        }
    }

    /// Counter deltas accumulated since `epoch` was snapshotted. Only
    /// meaningful while all ranks are quiescent (between barriers) —
    /// in-flight collectives would be split across the boundary.
    pub fn delta_since(&self, epoch: &CommStatsSnapshot) -> CommStatsSnapshot {
        let now = self.snapshot();
        CommStatsSnapshot {
            bytes: now.bytes - epoch.bytes,
            rounds: now.rounds - epoch.rounds,
            wait_ns: now.wait_ns - epoch.wait_ns,
            hops: now.hops - epoch.hops,
        }
    }
}

/// Plain-value copy of [`CommStats`] at one instant (or the difference
/// of two instants — see [`CommStats::delta_since`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CommStatsSnapshot {
    /// Bytes sent + received.
    pub bytes: u64,
    /// Collective calls (per participating rank).
    pub rounds: u64,
    /// Blocked nanoseconds across ranks.
    pub wait_ns: u64,
    /// Point-to-point legs.
    pub hops: u64,
}

impl std::ops::AddAssign for CommStatsSnapshot {
    fn add_assign(&mut self, rhs: Self) {
        self.bytes += rhs.bytes;
        self.rounds += rhs.rounds;
        self.wait_ns += rhs.wait_ns;
        self.hops += rhs.hops;
    }
}

/// Fold per-origin contributions into their mean, summing **in rank
/// order** (0, 1, …, W−1) and then scaling by 1/W — the one reduction
/// kernel every algorithm funnels through, and the reason flat, ring,
/// and tree collectives are bit-identical (f32 addition is commutative
/// but not associative; a topology-dependent order would let the
/// algorithms drift apart in the low bits).
pub(crate) fn mean_in_rank_order(
    world: usize,
    len: usize,
    contributions: &[(usize, Vec<f32>)],
) -> Vec<f32> {
    let mut by_rank: Vec<Option<&Vec<f32>>> = vec![None; world];
    for (origin, data) in contributions.iter() {
        assert!(by_rank[*origin].is_none(), "rank {origin} contributed twice");
        by_rank[*origin] = Some(data);
    }
    mean_of_ranked(world, len, &by_rank)
}

/// The shared core of every mean-reduce: contributions indexed by rank,
/// summed 0 → W−1 and then scaled. [`SharedMemComm`]'s staged sessions
/// and the ring/tree [`mean_in_rank_order`] both funnel here, so there
/// is exactly one reduction kernel to keep bit-identical.
fn mean_of_ranked(world: usize, len: usize, by_rank: &[Option<&Vec<f32>>]) -> Vec<f32> {
    let mut acc = by_rank[0].expect("rank 0 contribution").clone();
    assert_eq!(acc.len(), len, "collective length mismatch");
    for c in by_rank.iter().skip(1) {
        let c = c.expect("contribution");
        assert_eq!(c.len(), len, "collective length mismatch");
        for (a, b) in acc.iter_mut().zip(c.iter()) {
            *a += *b;
        }
    }
    let inv = 1.0 / world as f32;
    for a in acc.iter_mut() {
        *a *= inv;
    }
    acc
}

/// Which ZeRO shard stage a DDP run applies to the flat bucket arenas
/// (after Xu et al. 2020 and the ZeRO staging of Rajbhandari et al.):
/// each stage shards one more per-replica arena across the world,
/// trading collectives for memory while staying bit-identical to
/// unsharded training (the engine's standing invariant).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ShardStage {
    /// Fully replicated: every rank holds full grads, state, and values.
    None,
    /// ZeRO-1: optimizer state + the fused update shard (reduce-scatter
    /// gradients, update own shard, all-gather values). Grad and value
    /// arenas stay full on every rank.
    Zero1,
    /// ZeRO-2: additionally shard the gradient arenas — after the
    /// drain-point reduce-scatter a rank keeps only its shard slice and
    /// frees the rest, so steady-state grad residency is 1/W (grads are
    /// transiently full during backward, which computes them locally).
    Zero2,
    /// ZeRO-3: additionally shard the parameter value arenas — values
    /// live shard-resident between steps, all-gather per bucket on first
    /// touch of the next forward, and release after the post-backward
    /// update.
    Zero3,
}

impl ShardStage {
    /// All stages, in presentation order.
    pub const ALL: [ShardStage; 4] =
        [ShardStage::None, ShardStage::Zero1, ShardStage::Zero2, ShardStage::Zero3];

    /// Stable identifier used by CLI flags and bench tables.
    pub fn label(&self) -> &'static str {
        match self {
            ShardStage::None => "none",
            ShardStage::Zero1 => "zero1",
            ShardStage::Zero2 => "zero2",
            ShardStage::Zero3 => "zero3",
        }
    }

    /// Any sharding at all (stage ≥ 1): updates reduce-scatter and touch
    /// only the rank's shard; optimizer state allocates shard-only.
    pub fn sharded(&self) -> bool {
        !matches!(self, ShardStage::None)
    }

    /// Stage ≥ 2: gradient arenas narrow to the shard after the update.
    pub fn shards_grads(&self) -> bool {
        matches!(self, ShardStage::Zero2 | ShardStage::Zero3)
    }

    /// Stage 3: value arenas are shard-resident between steps and
    /// all-gather on first touch of the next forward.
    pub fn shards_values(&self) -> bool {
        matches!(self, ShardStage::Zero3)
    }
}

impl std::str::FromStr for ShardStage {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "none" | "0" | "off" => Ok(ShardStage::None),
            "zero1" | "1" => Ok(ShardStage::Zero1),
            "zero2" | "2" => Ok(ShardStage::Zero2),
            "zero3" | "3" => Ok(ShardStage::Zero3),
            _ => Err(format!("unknown shard stage '{s}' (none, zero1, zero2, zero3)")),
        }
    }
}

/// Collective tags: every in-flight collective is identified by a tag so
/// ranks can issue collectives for *different* schedulable units in
/// different orders (worker-pool overlap) without cross-talk.
pub mod tags {
    /// The scalar loss all-reduce (per training step).
    pub const LOSS: u64 = u64::MAX;

    /// The global-gradient-norm partial-sum all-reduce (sharded
    /// global-information optimizers — one scalar per rank per step).
    pub const NORM: u64 = u64::MAX - 1;

    /// Gradient reduce of schedulable unit `unit`.
    pub fn grad(unit: usize) -> u64 {
        (1u64 << 56) | unit as u64
    }

    /// Gradient reduce of chunk `chunk` of schedulable unit `unit` — the
    /// per-chunk overlap jobs of backward-fusion (`exec`'s
    /// `comm_chunk_bytes`). The limits are asserted: silently aliasing
    /// two chunks onto one tag would pair mismatched collectives.
    pub fn grad_chunk(unit: usize, chunk: usize) -> u64 {
        assert!(unit < 1 << 40, "grad_chunk: unit {unit} overflows the tag namespace");
        assert!(chunk < 1 << 16, "grad_chunk: chunk {chunk} overflows the tag namespace");
        (4u64 << 56) | ((chunk as u64) << 40) | unit as u64
    }

    /// Value all-gather of schedulable unit `unit`: post-update under
    /// ZeRO-1/2, pre-forward gather-on-first-touch under ZeRO-3, and the
    /// end-of-run / checkpoint value materialization.
    pub fn value(unit: usize) -> u64 {
        (2u64 << 56) | unit as u64
    }

    /// Value all-gather of chunk `chunk` of unit `unit` — the per-chunk
    /// value leg of chunked ZeRO-1/2 overlap jobs (pairs with
    /// [`grad_chunk`]'s reduce leg).
    pub fn value_chunk(unit: usize, chunk: usize) -> u64 {
        assert!(unit < 1 << 40, "value_chunk: unit {unit} overflows the tag namespace");
        assert!(chunk < 1 << 16, "value_chunk: chunk {chunk} overflows the tag namespace");
        (5u64 << 56) | ((chunk as u64) << 40) | unit as u64
    }

    /// Optimizer-state all-gather of `unit`'s state slot `slot`
    /// (checkpoint gather).
    pub fn state(unit: usize, slot: usize) -> u64 {
        (3u64 << 56) | ((slot as u64) << 40) | unit as u64
    }

    /// Forward activation message crossing pipeline-stage boundary
    /// `boundary` (between stage `boundary` and stage `boundary + 1`).
    /// Deliberately unit-less ([`unit_of`] returns `None`): activation
    /// traffic rides a dedicated bounded mailbox, never a collective
    /// session, and must not alias any training unit's tag sequence.
    pub fn act_fwd(boundary: usize) -> u64 {
        (7u64 << 56) | boundary as u64
    }

    /// Backward activation-gradient message crossing pipeline-stage
    /// boundary `boundary` (stage `boundary + 1` back to `boundary`).
    pub fn act_bwd(boundary: usize) -> u64 {
        (8u64 << 56) | boundary as u64
    }

    /// Tag-namespace prefix of the tensor-parallel leg — the routing
    /// key [`crate::comm::p2p::ActNet`] uses to account TP traffic on
    /// [`super::CommStats::tp_bytes`] instead of the pipeline p2p leg.
    pub const TP_PREFIX: u64 = 9;

    /// Tensor-parallel partial-output exchange at sync point `point`
    /// (an even/odd encoding of the layer's node id × forward/backward
    /// direction — see `exec`'s TP fold). Deliberately unit-less
    /// ([`unit_of`] returns `None`): TP partials ride the bounded p2p
    /// mailbox between the ranks of one TP group, never a collective
    /// session, and must not alias any training unit's tag sequence.
    pub fn tp(point: usize) -> u64 {
        (TP_PREFIX << 56) | point as u64
    }

    /// Calibration-probe collective `k` — the synthetic warm-up
    /// all-reduces `--calibrate` times to sample blocked time. The
    /// namespace is deliberately unit-less ([`unit_of`] returns `None`),
    /// so probes route to a mixed session's default algorithm and never
    /// alias a training unit's tag sequence.
    pub fn probe(k: usize) -> u64 {
        (6u64 << 56) | k as u64
    }

    /// The schedulable unit a tag addresses, if any — the routing key of
    /// mixed-algorithm sessions ([`crate::comm::plan::MixedComm`]). The
    /// scalar [`LOSS`] / [`NORM`] tags (and any unrecognized namespace)
    /// return `None` and route to the session's default algorithm.
    pub fn unit_of(tag: u64) -> Option<usize> {
        if tag == LOSS || tag == NORM {
            return None;
        }
        match tag >> 56 {
            1..=5 => Some((tag & ((1u64 << 40) - 1)) as usize),
            _ => None,
        }
    }
}

/// Collectives over equal-length f32 buffers among a fixed set of ranks.
///
/// All ranks must call the *same* collective with the *same* tag and
/// buffer length; the tag decouples issue order across ranks.
pub trait Communicator: Send + Sync {
    /// Number of participating ranks.
    fn world(&self) -> usize;

    /// Average `data` across all ranks, in place on every rank. The
    /// reduction order is rank order on every rank, so all ranks end
    /// with bit-identical buffers.
    fn all_reduce_mean(&self, rank: usize, tag: u64, data: &mut [f32]);

    /// Average across ranks, but each rank receives only its own shard
    /// (`shard_span(data.len(), world, rank)`), written in place into
    /// that region of `data`; the rest of `data` is left untouched. The
    /// shard's values are bit-identical to the same region of an
    /// `all_reduce_mean`.
    fn reduce_scatter_mean(&self, rank: usize, tag: u64, data: &mut [f32]) {
        let spans = shard_partition(data.len(), self.world());
        self.reduce_scatter_mean_spans(rank, tag, data, &spans);
    }

    /// [`Communicator::reduce_scatter_mean`] with an explicit ownership
    /// partition: rank `r` receives `spans[r]` instead of the balanced
    /// `shard_span`. The spans must tile `data` contiguously in rank
    /// order (empty spans allowed). This is the primitive the chunked
    /// ZeRO path needs — a chunk's collective hands each rank the
    /// intersection of its *bucket-level* shard with the chunk, which is
    /// generally not the balanced partition of the chunk itself.
    fn reduce_scatter_mean_spans(
        &self,
        rank: usize,
        tag: u64,
        data: &mut [f32],
        spans: &[(usize, usize)],
    );

    /// Each rank contributes its own shard region of `data`; on return
    /// `data` is fully populated with every rank's shard on every rank.
    fn all_gather(&self, rank: usize, tag: u64, data: &mut [f32]) {
        let spans = shard_partition(data.len(), self.world());
        self.all_gather_spans(rank, tag, data, &spans);
    }

    /// [`Communicator::all_gather`] with an explicit ownership partition
    /// (same contract as [`Communicator::reduce_scatter_mean_spans`]):
    /// rank `r` contributes `spans[r]` of `data`.
    fn all_gather_spans(&self, rank: usize, tag: u64, data: &mut [f32], spans: &[(usize, usize)]);

    /// The unified accounting for every collective issued through this
    /// communicator.
    fn stats(&self) -> &CommStats;
}

/// Check a spans argument against the [`Communicator`] span contract:
/// one span per rank, tiling `[0, n)` contiguously in rank order.
pub(crate) fn assert_spans_tile(spans: &[(usize, usize)], world: usize, n: usize) {
    assert_eq!(spans.len(), world, "span collective: one span per rank");
    let mut next = 0usize;
    for (rank, (off, len)) in spans.iter().enumerate() {
        assert_eq!(*off, next, "span collective: rank {rank} span not contiguous");
        next = off + len;
    }
    assert_eq!(next, n, "span collective: spans must tile the buffer");
}

/// Everything the executor needs to participate in collectives: the
/// communicator, this replica's rank, which ZeRO shard stage the run
/// applies to the flat bucket arenas, and (under `--algo auto`) the
/// per-bucket comm plan.
#[derive(Clone)]
pub struct CommCtx {
    /// The collective backend shared by all ranks.
    pub comm: Arc<dyn Communicator>,
    /// This replica's rank in `[0, world)`.
    pub rank: usize,
    /// ZeRO stage: `Zero1` shards state + update, `Zero2` additionally
    /// the gradient arenas, `Zero3` additionally the value arenas (see
    /// [`ShardStage`]).
    pub stage: ShardStage,
    /// The planner's per-bucket algorithm + chunk-split choices
    /// ([`crate::comm::plan`]), when a run uses `--algo auto`. The
    /// executor reads per-unit chunk caps from it; the communicator
    /// itself is a [`MixedComm`] routing each unit's tags to its
    /// planned algorithm. `None` on fixed-algorithm runs.
    pub plan: Option<Arc<StepPlan>>,
    /// The rank grid the run communicates over. Decides the ZeRO shard
    /// *placement*: on a two-tier grid ownership spans are node-local
    /// ([`crate::tensor::flat::node_local_span`]) so cross-node gathers
    /// move each node's region over its uplink once per node; on a flat
    /// grid this degenerates to the balanced `shard_span`.
    pub topo: Topology,
}

impl CommCtx {
    /// A fixed-algorithm context (no per-bucket plan) over a flat grid.
    pub fn new(comm: Arc<dyn Communicator>, rank: usize, stage: ShardStage) -> Self {
        let world = comm.world();
        Self { comm, rank, stage, plan: None, topo: Topology::flat(world) }
    }

    /// This rank's owned region of a `total`-element arena under the
    /// run's shard placement (node-local on two-tier grids).
    pub fn placement_span(&self, total: usize) -> (usize, usize) {
        crate::tensor::flat::node_local_span(total, self.topo.world, self.topo.rpn(), self.rank)
    }

    /// The full rank-ordered ownership partition of a `total`-element
    /// arena under the run's shard placement — what the `_spans`
    /// collectives are handed on the ZeRO paths.
    pub fn placement_spans(&self, total: usize) -> Vec<(usize, usize)> {
        crate::tensor::flat::node_local_spans(total, self.topo.world, self.topo.rpn())
    }
}

enum ReduceOp {
    /// Elementwise sum in rank order, scaled by 1/world.
    MeanSum,
    /// Concatenate contributions in rank order (shard reassembly).
    Concat,
}

struct Session {
    stage: Vec<Option<Vec<f32>>>,
    arrived: usize,
    departed: usize,
    result: Option<Arc<Vec<f32>>>,
}

impl Session {
    fn new(world: usize) -> Self {
        Self {
            stage: (0..world).map(|_| None).collect(),
            arrived: 0,
            departed: 0,
            result: None,
        }
    }
}

struct Inner {
    /// In-flight sessions keyed by `(tag, per-rank sequence number)`.
    sessions: HashMap<(u64, u64), Session>,
    /// Per-rank count of collectives issued per tag: the k-th call with
    /// a tag on one rank pairs with the k-th call on every other rank,
    /// so a fast rank can start step k+1's collective for a bucket
    /// before a slow rank has left step k's.
    next_seq: Vec<HashMap<u64, u64>>,
}

/// Shared-memory [`Communicator`]: ranks are threads of one process and
/// collectives meet in tag-matched staging sessions.
pub struct SharedMemComm {
    world: usize,
    inner: Mutex<Inner>,
    ready: Condvar,
    stats: Arc<CommStats>,
}

impl SharedMemComm {
    /// A communicator for `world` ranks (threads).
    pub fn new(world: usize) -> Self {
        Self::with_stats(world, Arc::new(CommStats::default()))
    }

    /// [`SharedMemComm::new`] recording into an externally shared
    /// [`CommStats`] (mixed-algorithm sessions).
    pub fn with_stats(world: usize, stats: Arc<CommStats>) -> Self {
        assert!(world > 0, "communicator needs at least one rank");
        Self {
            world,
            inner: Mutex::new(Inner {
                sessions: HashMap::new(),
                next_seq: (0..world).map(|_| HashMap::new()).collect(),
            }),
            ready: Condvar::new(),
            stats,
        }
    }

    /// Join the session for `tag`, contribute `contribution`, block until
    /// all ranks have contributed, and return the (shared) reduced
    /// result. The last rank to arrive performs the reduction.
    fn collective(
        &self,
        rank: usize,
        tag: u64,
        contribution: Vec<f32>,
        op: ReduceOp,
    ) -> Arc<Vec<f32>> {
        assert!(rank < self.world, "rank {rank} out of range");
        let mut inner = self.inner.lock().unwrap();
        let seq = {
            let c = inner.next_seq[rank].entry(tag).or_insert(0);
            let s = *c;
            *c += 1;
            s
        };
        let key = (tag, seq);
        let world = self.world;
        let is_last = {
            let sess = inner
                .sessions
                .entry(key)
                .or_insert_with(|| Session::new(world));
            assert!(
                sess.stage[rank].is_none(),
                "rank {rank} contributed twice to tag {tag:#x}"
            );
            sess.stage[rank] = Some(contribution);
            sess.arrived += 1;
            sess.arrived == world
        };
        let result = if is_last {
            // Run the O(len·world) reduction *outside* the session lock:
            // other tags' sessions keep making progress while this one
            // reduces — the whole point of tag-matched concurrency. The
            // session cannot be removed meanwhile (ranks depart only
            // after the result is published below).
            let stage = {
                let sess = inner.sessions.get_mut(&key).unwrap();
                std::mem::take(&mut sess.stage)
            };
            drop(inner);
            let reduced = Arc::new(reduce_stage(&op, world, &stage));
            inner = self.inner.lock().unwrap();
            let sess = inner.sessions.get_mut(&key).unwrap();
            sess.result = Some(Arc::clone(&reduced));
            self.ready.notify_all();
            reduced
        } else {
            loop {
                if let Some(r) = inner.sessions.get(&key).and_then(|s| s.result.clone()) {
                    break r;
                }
                inner = self.ready.wait(inner).unwrap();
            }
        };
        let done = {
            let sess = inner.sessions.get_mut(&key).unwrap();
            sess.departed += 1;
            sess.departed == world
        };
        if done {
            inner.sessions.remove(&key);
        }
        result
    }
}

fn reduce_stage(op: &ReduceOp, world: usize, stage: &[Option<Vec<f32>>]) -> Vec<f32> {
    match op {
        ReduceOp::MeanSum => {
            // Rank order, starting from rank 0, on every rank — the one
            // shared reduction kernel (see `mean_of_ranked`), so the
            // flat session cannot drift from the ring/tree algorithms.
            let by_rank: Vec<Option<&Vec<f32>>> = stage.iter().map(|s| s.as_ref()).collect();
            let len = by_rank[0].map_or(0, |c| c.len());
            mean_of_ranked(world, len, &by_rank)
        }
        ReduceOp::Concat => stage
            .iter()
            .flat_map(|s| s.as_ref().expect("contribution").iter().copied())
            .collect(),
    }
}

impl Communicator for SharedMemComm {
    fn world(&self) -> usize {
        self.world
    }

    fn all_reduce_mean(&self, rank: usize, tag: u64, data: &mut [f32]) {
        let t0 = Instant::now();
        let n = data.len();
        let result = self.collective(rank, tag, data.to_vec(), ReduceOp::MeanSum);
        data.copy_from_slice(&result);
        self.stats.record(n * 4, n * 4, 2, t0);
    }

    fn reduce_scatter_mean_spans(
        &self,
        rank: usize,
        tag: u64,
        data: &mut [f32],
        spans: &[(usize, usize)],
    ) {
        let t0 = Instant::now();
        let n = data.len();
        assert_spans_tile(spans, self.world, n);
        let (off, len) = spans[rank];
        let result = self.collective(rank, tag, data.to_vec(), ReduceOp::MeanSum);
        data[off..off + len].copy_from_slice(&result[off..off + len]);
        self.stats.record(n * 4, len * 4, 2, t0);
    }

    fn all_gather_spans(&self, rank: usize, tag: u64, data: &mut [f32], spans: &[(usize, usize)]) {
        let t0 = Instant::now();
        let n = data.len();
        assert_spans_tile(spans, self.world, n);
        let (off, len) = spans[rank];
        let result = self.collective(rank, tag, data[off..off + len].to_vec(), ReduceOp::Concat);
        assert_eq!(result.len(), n, "all_gather: shards must tile the buffer");
        data.copy_from_slice(&result);
        self.stats.record(len * 4, n * 4, 2, t0);
    }

    fn stats(&self) -> &CommStats {
        &self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::flat::shard_span;
    use std::sync::Mutex as StdMutex;

    #[test]
    fn all_reduce_means_and_is_bit_identical_across_ranks() {
        let world = 3;
        let comm = Arc::new(SharedMemComm::new(world));
        let outs = Arc::new(StdMutex::new(vec![Vec::new(); world]));
        std::thread::scope(|s| {
            for rank in 0..world {
                let comm = Arc::clone(&comm);
                let outs = Arc::clone(&outs);
                s.spawn(move || {
                    let mut d = vec![(rank + 1) as f32 * 0.1; 5];
                    comm.all_reduce_mean(rank, tags::grad(0), &mut d);
                    outs.lock().unwrap()[rank] = d;
                });
            }
        });
        let outs = outs.lock().unwrap();
        for r in 1..world {
            assert_eq!(outs[0], outs[r], "ranks must agree bit-for-bit");
        }
        assert!((outs[0][0] - 0.2).abs() < 1e-6, "mean of 0.1, 0.2, 0.3");
        assert_eq!(comm.stats().rounds.load(Ordering::Relaxed), world as u64);
        assert!(comm.stats().bytes.load(Ordering::Relaxed) > 0);
    }

    #[test]
    fn reduce_scatter_shard_matches_all_reduce() {
        let world = 4;
        let comm = Arc::new(SharedMemComm::new(world));
        let n = 10; // non-divisible by world: remainder spread over early ranks
        let outs = Arc::new(StdMutex::new(vec![(Vec::new(), Vec::new()); world]));
        std::thread::scope(|s| {
            for rank in 0..world {
                let comm = Arc::clone(&comm);
                let outs = Arc::clone(&outs);
                s.spawn(move || {
                    let base: Vec<f32> = (0..n).map(|i| (i * (rank + 1)) as f32).collect();
                    let mut ar = base.clone();
                    comm.all_reduce_mean(rank, tags::grad(1), &mut ar);
                    let mut rs = base.clone();
                    comm.reduce_scatter_mean(rank, tags::grad(2), &mut rs);
                    outs.lock().unwrap()[rank] = (ar, rs);
                });
            }
        });
        let outs = outs.lock().unwrap();
        for rank in 0..world {
            let (ar, rs) = &outs[rank];
            let (off, len) = shard_span(n, world, rank);
            assert_eq!(&ar[off..off + len], &rs[off..off + len], "shard values identical");
            // outside the shard, reduce-scatter leaves the local buffer
            for i in 0..n {
                if i < off || i >= off + len {
                    assert_eq!(rs[i], (i * (rank + 1)) as f32, "untouched outside shard");
                }
            }
        }
    }

    #[test]
    fn all_gather_reassembles_shards() {
        let world = 3;
        let n = 8;
        let comm = Arc::new(SharedMemComm::new(world));
        let outs = Arc::new(StdMutex::new(vec![Vec::new(); world]));
        // the "true" full buffer every rank should end with
        let full: Vec<f32> = (0..n).map(|i| i as f32 * 2.0).collect();
        std::thread::scope(|s| {
            for rank in 0..world {
                let comm = Arc::clone(&comm);
                let outs = Arc::clone(&outs);
                let full = full.clone();
                s.spawn(move || {
                    // each rank knows only its own shard
                    let mut d = vec![0.0f32; n];
                    let (off, len) = shard_span(n, world, rank);
                    d[off..off + len].copy_from_slice(&full[off..off + len]);
                    comm.all_gather(rank, tags::value(0), &mut d);
                    outs.lock().unwrap()[rank] = d;
                });
            }
        });
        let outs = outs.lock().unwrap();
        for rank in 0..world {
            assert_eq!(outs[rank], full, "rank {rank} reassembled");
        }
    }

    /// The property the worker-pool overlap depends on: each rank may
    /// have several collectives for *different* tags in flight at once
    /// (its pool workers), and the sessions pair up by tag no matter
    /// how the threads interleave.
    #[test]
    fn tags_decouple_concurrent_sessions_across_ranks() {
        let comm = Arc::new(SharedMemComm::new(2));
        let outs = Arc::new(StdMutex::new([[0.0f32; 2]; 2]));
        std::thread::scope(|s| {
            for rank in 0..2 {
                for (slot, tag) in [tags::grad(7), tags::grad(8)].into_iter().enumerate() {
                    let comm = Arc::clone(&comm);
                    let outs = Arc::clone(&outs);
                    s.spawn(move || {
                        let base = if slot == 0 { rank as f32 } else { 10.0 + rank as f32 };
                        let mut d = [base];
                        comm.all_reduce_mean(rank, tag, &mut d);
                        outs.lock().unwrap()[rank][slot] = d[0];
                    });
                }
            }
        });
        let outs = outs.lock().unwrap();
        for rank in 0..2 {
            assert_eq!(outs[rank][0], 0.5, "mean of 0, 1");
            assert_eq!(outs[rank][1], 10.5, "mean of 10, 11");
        }
    }

    #[test]
    fn tag_reuse_across_rounds_is_sequenced() {
        let comm = Arc::new(SharedMemComm::new(2));
        std::thread::scope(|s| {
            for rank in 0..2 {
                let comm = Arc::clone(&comm);
                s.spawn(move || {
                    for round in 0..5 {
                        let mut d = vec![rank as f32 + round as f32; 4];
                        comm.all_reduce_mean(rank, tags::grad(3), &mut d);
                        assert_eq!(d[0], 0.5 + round as f32);
                    }
                });
            }
        });
        assert_eq!(comm.stats().rounds.load(Ordering::Relaxed), 10);
    }

    #[test]
    fn tag_unit_decoding_routes_every_namespace() {
        assert_eq!(tags::unit_of(tags::grad(7)), Some(7));
        assert_eq!(tags::unit_of(tags::value(3)), Some(3));
        assert_eq!(tags::unit_of(tags::grad_chunk(5, 9)), Some(5));
        assert_eq!(tags::unit_of(tags::value_chunk(4, 2)), Some(4));
        assert_eq!(tags::unit_of(tags::state(6, 1)), Some(6));
        assert_eq!(tags::unit_of(tags::LOSS), None);
        assert_eq!(tags::unit_of(tags::NORM), None);
        // activation traffic never routes to a collective session
        assert_eq!(tags::unit_of(tags::act_fwd(2)), None);
        assert_eq!(tags::unit_of(tags::act_bwd(0)), None);
        assert_eq!(tags::unit_of(tags::tp(0)), None);
        assert_eq!(tags::unit_of(tags::tp(11)), None);
    }

    #[test]
    fn shard_stage_parse_label_roundtrip() {
        for stage in ShardStage::ALL {
            assert_eq!(stage.label().parse::<ShardStage>().unwrap(), stage);
        }
        assert_eq!("2".parse::<ShardStage>().unwrap(), ShardStage::Zero2);
        assert!("zero4".parse::<ShardStage>().is_err());
        assert!(!ShardStage::None.sharded());
        assert!(ShardStage::Zero1.sharded() && !ShardStage::Zero1.shards_grads());
        assert!(ShardStage::Zero2.shards_grads() && !ShardStage::Zero2.shards_values());
        assert!(ShardStage::Zero3.shards_grads() && ShardStage::Zero3.shards_values());
    }

    /// Span-parameterized collectives: an uneven rank-ordered partition
    /// (the chunk ∩ shard case) scatters/gathers exactly those spans,
    /// bit-identical to the same regions of a full all-reduce.
    #[test]
    fn span_collectives_respect_explicit_partitions() {
        let world = 3;
        let n = 8;
        // deliberately unbalanced, with one empty span
        let spans = [(0usize, 5usize), (5, 0), (5, 3)];
        let comm = Arc::new(SharedMemComm::new(world));
        let outs = Arc::new(StdMutex::new(vec![(Vec::new(), Vec::new()); world]));
        std::thread::scope(|s| {
            for rank in 0..world {
                let comm = Arc::clone(&comm);
                let outs = Arc::clone(&outs);
                s.spawn(move || {
                    let base: Vec<f32> = (0..n).map(|i| (i * (rank + 1)) as f32).collect();
                    let mut ar = base.clone();
                    comm.all_reduce_mean(rank, tags::grad(4), &mut ar);
                    let mut rs = base.clone();
                    comm.reduce_scatter_mean_spans(rank, tags::grad(5), &mut rs, &spans);
                    outs.lock().unwrap()[rank] = (ar, rs);
                });
            }
        });
        let outs = outs.lock().unwrap();
        for rank in 0..world {
            let (ar, rs) = &outs[rank];
            let (off, len) = spans[rank];
            assert_eq!(&ar[off..off + len], &rs[off..off + len], "own span reduced");
            for i in 0..n {
                if i < off || i >= off + len {
                    assert_eq!(rs[i], (i * (rank + 1)) as f32, "untouched outside span");
                }
            }
        }
        // gather with the same partition reassembles the full buffer
        let full: Vec<f32> = (0..n).map(|i| i as f32 + 0.5).collect();
        let outs = Arc::new(StdMutex::new(vec![Vec::new(); world]));
        std::thread::scope(|s| {
            for rank in 0..world {
                let comm = Arc::clone(&comm);
                let outs = Arc::clone(&outs);
                let full = full.clone();
                s.spawn(move || {
                    let mut d = vec![0.0f32; n];
                    let (off, len) = spans[rank];
                    d[off..off + len].copy_from_slice(&full[off..off + len]);
                    comm.all_gather_spans(rank, tags::value(9), &mut d, &spans);
                    outs.lock().unwrap()[rank] = d;
                });
            }
        });
        for rank in 0..world {
            assert_eq!(outs.lock().unwrap()[rank], full, "rank {rank} reassembled");
        }
    }

    #[test]
    fn world_one_is_identity() {
        let comm = SharedMemComm::new(1);
        let mut d = vec![3.0f32, -1.0];
        comm.all_reduce_mean(0, tags::LOSS, &mut d);
        assert_eq!(d, vec![3.0, -1.0]);
        let mut d = vec![5.0f32; 4];
        comm.all_gather(0, tags::value(0), &mut d);
        assert_eq!(d, vec![5.0; 4]);
    }
}
