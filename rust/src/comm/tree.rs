//! Binomial-tree collectives: latency-optimal all-reduce as a
//! ⌈log₂W⌉-round reduce to rank 0 followed by a mirror-image broadcast.
//!
//! In reduce round `k` (k = 0, 1, …), every rank whose low `k` bits are
//! zero is still active; the active ranks with bit `k` set send their
//! partial to `rank − 2ᵏ` and retire. After ⌈log₂W⌉ rounds rank 0 holds
//! every contribution; the broadcast walks the same edges in reverse.
//! The critical path is `2⌈log₂W⌉` hops of the **full** buffer — the
//! latency-optimal schedule (vs the ring's `2(W−1)` hops of `1/W`
//! buffers), which wins for small buffers and loses bandwidth for big
//! ones; `memsim`'s `Interconnect` prices the crossover.
//!
//! Bit-determinism: reduce messages carry per-origin contributions
//! ([`super::p2p`]) and rank 0 folds them in rank order, so results are
//! bit-identical to [`super::SharedMemComm`] and [`super::RingComm`] —
//! while [`super::CommStats`] charges the full-buffer bytes a real tree
//! would move per hop. The single-thread ordering contract of
//! [`super::RingComm`] applies unchanged.

use super::p2p::{Acct, Mailbox, MsgKey, Payload};
use super::{assert_spans_tile, mean_in_rank_order, CommStats, Communicator};
use std::sync::Arc;
use std::time::Instant;

/// Binomial-tree [`Communicator`]: ⌈log₂W⌉ reduce rounds to rank 0 plus
/// the mirrored broadcast.
pub struct TreeComm {
    world: usize,
    mail: Mailbox,
    stats: Arc<CommStats>,
}

/// ⌈log₂ world⌉ — the number of reduce (and broadcast) rounds.
pub(crate) fn tree_rounds(world: usize) -> u32 {
    usize::BITS - (world - 1).leading_zeros()
}

impl TreeComm {
    /// A binomial-tree communicator for `world` ranks.
    pub fn new(world: usize) -> Self {
        Self::with_stats(world, Arc::new(CommStats::default()))
    }

    /// [`TreeComm::new`] recording into an externally shared
    /// [`CommStats`] (mixed-algorithm sessions).
    pub fn with_stats(world: usize, stats: Arc<CommStats>) -> Self {
        assert!(world > 0, "communicator needs at least one rank");
        Self { world, mail: Mailbox::new(world), stats }
    }

    /// Binomial reduce to rank 0: non-roots post their accumulated
    /// contribution list up the tree at round `trailing_zeros(rank)` and
    /// return `None`; rank 0 returns the full contribution list. Each
    /// message is charged as one full-buffer hop.
    fn reduce_to_root(
        &self,
        rank: usize,
        tag: u64,
        seq: u64,
        data: &[f32],
        acct: &mut Acct,
    ) -> Option<Payload> {
        let w = self.world;
        let bytes = 4 * data.len();
        let mut carry: Payload = vec![(rank, data.to_vec())];
        for k in 0..tree_rounds(w) {
            let d = 1usize << k;
            if rank % (2 * d) == d {
                // this round's sender: ship the partial and retire
                self.mail.post(
                    MsgKey { tag, seq, leg: k, from: rank, to: rank - d },
                    std::mem::take(&mut carry),
                );
                acct.sent += bytes;
                acct.legs += 1;
                return None;
            }
            // still active: absorb the partner's partial if it exists
            if rank + d < w {
                let incoming =
                    self.mail.take(MsgKey { tag, seq, leg: k, from: rank + d, to: rank });
                carry.extend(incoming);
                acct.received += bytes;
                acct.legs += 1;
            }
        }
        Some(carry)
    }

    /// Mirror-image binomial broadcast of `result` from rank 0: each rank
    /// receives from its parent (edge round = `trailing_zeros(rank)`),
    /// then forwards to its children in descending round order. An edge
    /// of round `j` is keyed `leg_base + j`; callers pick a `leg_base`
    /// that cannot collide with the legs already spent (the reduce's
    /// `0..rounds`, or the all-gather's star leg 0).
    #[allow(clippy::too_many_arguments)]
    fn broadcast_from_root(
        &self,
        rank: usize,
        tag: u64,
        seq: u64,
        result: Option<Vec<f32>>,
        n: usize,
        leg_base: u32,
        acct: &mut Acct,
    ) -> Vec<f32> {
        let w = self.world;
        let bytes = 4 * n;
        let (result, my_round) = match result {
            Some(r) => (r, tree_rounds(w)),
            None => {
                let k = rank.trailing_zeros();
                let parent = rank - (1usize << k);
                let mut msg =
                    self.mail.take(MsgKey { tag, seq, leg: leg_base + k, from: parent, to: rank });
                acct.received += bytes;
                acct.legs += 1;
                (msg.pop().expect("broadcast payload").1, k)
            }
        };
        for j in (0..my_round).rev() {
            let child = rank + (1usize << j);
            if child < w {
                self.mail.post(
                    MsgKey { tag, seq, leg: leg_base + j, from: rank, to: child },
                    vec![(rank, result.clone())],
                );
                acct.sent += bytes;
                acct.legs += 1;
            }
        }
        result
    }
}

impl Communicator for TreeComm {
    fn world(&self) -> usize {
        self.world
    }

    fn all_reduce_mean(&self, rank: usize, tag: u64, data: &mut [f32]) {
        let t0 = Instant::now();
        let w = self.world;
        assert!(rank < w, "rank {rank} out of range");
        if w == 1 {
            self.stats.record(0, 0, 0, t0);
            return;
        }
        let seq = self.mail.next_seq(rank, tag);
        let mut acct = Acct::default();
        let n = data.len();
        let reduced = self
            .reduce_to_root(rank, tag, seq, data, &mut acct)
            .map(|carry| mean_in_rank_order(w, n, &carry));
        let result =
            self.broadcast_from_root(rank, tag, seq, reduced, n, tree_rounds(w), &mut acct);
        data.copy_from_slice(&result);
        self.stats.record(acct.sent, acct.received, acct.legs, t0);
    }

    fn reduce_scatter_mean_spans(
        &self,
        rank: usize,
        tag: u64,
        data: &mut [f32],
        spans: &[(usize, usize)],
    ) {
        let t0 = Instant::now();
        let w = self.world;
        assert!(rank < w, "rank {rank} out of range");
        assert_spans_tile(spans, w, data.len());
        if w == 1 {
            self.stats.record(0, 0, 0, t0);
            return;
        }
        let seq = self.mail.next_seq(rank, tag);
        let mut acct = Acct::default();
        let n = data.len();
        let rounds = tree_rounds(w);
        let (off, len) = spans[rank];
        match self.reduce_to_root(rank, tag, seq, data, &mut acct) {
            Some(carry) => {
                // root: compute the full mean, scatter each rank its span
                let full = mean_in_rank_order(w, n, &carry);
                for r in 1..w {
                    let (o, l) = spans[r];
                    self.mail.post(
                        MsgKey { tag, seq, leg: rounds, from: 0, to: r },
                        vec![(r, full[o..o + l].to_vec())],
                    );
                    acct.sent += 4 * l;
                    acct.legs += 1;
                }
                data[off..off + len].copy_from_slice(&full[off..off + len]);
            }
            None => {
                let mut msg =
                    self.mail.take(MsgKey { tag, seq, leg: rounds, from: 0, to: rank });
                data[off..off + len].copy_from_slice(&msg.pop().expect("scatter payload").1);
                acct.received += 4 * len;
                acct.legs += 1;
            }
        }
        self.stats.record(acct.sent, acct.received, acct.legs, t0);
    }

    fn all_gather_spans(&self, rank: usize, tag: u64, data: &mut [f32], spans: &[(usize, usize)]) {
        let t0 = Instant::now();
        let w = self.world;
        assert!(rank < w, "rank {rank} out of range");
        assert_spans_tile(spans, w, data.len());
        if w == 1 {
            self.stats.record(0, 0, 0, t0);
            return;
        }
        let seq = self.mail.next_seq(rank, tag);
        let mut acct = Acct::default();
        let n = data.len();
        let (off, len) = spans[rank];
        // star-gather the spans to rank 0 (leg 0 per edge), then
        // binomial-broadcast the assembled buffer (legs 1 + round)
        let assembled = if rank == 0 {
            let mut full = vec![0.0f32; n];
            full[off..off + len].copy_from_slice(&data[off..off + len]);
            for r in 1..w {
                let (o, l) = spans[r];
                let mut msg = self.mail.take(MsgKey { tag, seq, leg: 0, from: r, to: 0 });
                full[o..o + l].copy_from_slice(&msg.pop().expect("gather payload").1);
                acct.received += 4 * l;
                acct.legs += 1;
            }
            Some(full)
        } else {
            self.mail.post(
                MsgKey { tag, seq, leg: 0, from: rank, to: 0 },
                vec![(rank, data[off..off + len].to_vec())],
            );
            acct.sent += 4 * len;
            acct.legs += 1;
            None
        };
        // the gather used leg 0, so broadcast edges live at 1 + round
        let result = self.broadcast_from_root(rank, tag, seq, assembled, n, 1, &mut acct);
        data.copy_from_slice(&result);
        self.stats.record(acct.sent, acct.received, acct.legs, t0);
    }

    fn stats(&self) -> &CommStats {
        &self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::super::algo::{
        wire_all_gather, wire_all_reduce, wire_reduce_scatter, CommAlgo, Topology,
    };
    use super::super::{tags, SharedMemComm};
    use super::*;
    use std::sync::atomic::Ordering;
    use std::sync::{Arc, Mutex};

    fn drive(
        world: usize,
        n: usize,
        op: impl Fn(&dyn Communicator, usize, &mut [f32]) + Sync,
    ) -> (Vec<Vec<f32>>, Vec<Vec<f32>>) {
        let tree = Arc::new(TreeComm::new(world));
        let flat = Arc::new(SharedMemComm::new(world));
        let outs = Arc::new(Mutex::new(vec![(Vec::new(), Vec::new()); world]));
        let op = &op;
        std::thread::scope(|s| {
            for rank in 0..world {
                let tree = Arc::clone(&tree);
                let flat = Arc::clone(&flat);
                let outs = Arc::clone(&outs);
                s.spawn(move || {
                    let base: Vec<f32> =
                        (0..n).map(|i| (i as f32 - 2.1) * (rank as f32 + 0.9)).collect();
                    let mut t = base.clone();
                    op(tree.as_ref(), rank, &mut t);
                    let mut f = base.clone();
                    op(flat.as_ref(), rank, &mut f);
                    outs.lock().unwrap()[rank] = (t, f);
                });
            }
        });
        let outs = outs.lock().unwrap();
        let tree_outs = outs.iter().map(|(t, _)| t.clone()).collect();
        let flat_outs = outs.iter().map(|(_, f)| f.clone()).collect();
        (tree_outs, flat_outs)
    }

    fn assert_bit_equal(a: &[Vec<f32>], b: &[Vec<f32>], what: &str) {
        for (rank, (x, y)) in a.iter().zip(b.iter()).enumerate() {
            assert_eq!(x.len(), y.len());
            for (i, (u, v)) in x.iter().zip(y.iter()).enumerate() {
                assert_eq!(u.to_bits(), v.to_bits(), "{what}: rank {rank} elem {i}: {u} vs {v}");
            }
        }
    }

    #[test]
    fn rounds_are_ceil_log2() {
        for (w, r) in [(1usize, 0u32), (2, 1), (3, 2), (4, 2), (5, 3), (8, 3), (9, 4)] {
            assert_eq!(tree_rounds(w), r, "world {w}");
        }
    }

    /// Power-of-two and ragged world sizes both reduce bit-identically
    /// to the flat communicator — including W = 3 and 5, where some
    /// reduce rounds have no partner.
    #[test]
    fn all_reduce_bit_identical_to_flat_at_every_world_size() {
        for world in [1usize, 2, 3, 4, 5] {
            let (tree, flat) =
                drive(world, 10, |c, rank, d| c.all_reduce_mean(rank, tags::grad(0), d));
            assert_bit_equal(&tree, &flat, &format!("all_reduce world {world}"));
        }
    }

    #[test]
    fn reduce_scatter_and_all_gather_bit_identical_to_flat() {
        for world in [2usize, 3, 4, 5] {
            let (tree, flat) =
                drive(world, 11, |c, rank, d| c.reduce_scatter_mean(rank, tags::grad(1), d));
            assert_bit_equal(&tree, &flat, &format!("reduce_scatter world {world}"));
            let (tree, flat) =
                drive(world, 9, |c, rank, d| c.all_gather(rank, tags::value(0), d));
            assert_bit_equal(&tree, &flat, &format!("all_gather world {world}"));
        }
    }

    /// Satellite accounting check: a tree all-reduce is 2(W−1) full-size
    /// messages — W−1 up the tree, W−1 back down — counted at both ends.
    #[test]
    fn stats_match_closed_form() {
        for (world, n) in [(2usize, 8usize), (3, 10), (4, 10), (5, 6)] {
            let tree = Arc::new(TreeComm::new(world));
            std::thread::scope(|s| {
                for rank in 0..world {
                    let tree = Arc::clone(&tree);
                    s.spawn(move || {
                        let mut d = vec![rank as f32; n];
                        tree.all_reduce_mean(rank, tags::grad(7), &mut d);
                    });
                }
            });
            let want = wire_all_reduce(CommAlgo::Tree, n, &Topology::flat(world));
            assert_eq!(tree.stats.bytes.load(Ordering::Relaxed), want.bytes, "w={world} n={n}");
            assert_eq!(tree.stats.hops.load(Ordering::Relaxed), want.hops, "w={world} n={n}");
            assert_eq!(tree.stats.rounds.load(Ordering::Relaxed), world as u64);
            assert_eq!(want.bytes, 16 * n as u64 * (world as u64 - 1));
            assert_eq!(want.hops, 4 * (world as u64 - 1));
        }
    }

    #[test]
    fn phase_stats_match_closed_forms() {
        let world = 4;
        let n = 10;
        for (which, want) in [
            ("rs", wire_reduce_scatter(CommAlgo::Tree, n, &Topology::flat(world))),
            ("ag", wire_all_gather(CommAlgo::Tree, n, &Topology::flat(world))),
        ] {
            let tree = Arc::new(TreeComm::new(world));
            std::thread::scope(|s| {
                for rank in 0..world {
                    let tree = Arc::clone(&tree);
                    s.spawn(move || {
                        let mut d = vec![1.0f32; n];
                        if which == "rs" {
                            tree.reduce_scatter_mean(rank, tags::grad(0), &mut d);
                        } else {
                            tree.all_gather(rank, tags::value(0), &mut d);
                        }
                    });
                }
            });
            assert_eq!(tree.stats.bytes.load(Ordering::Relaxed), want.bytes, "{which}");
            assert_eq!(tree.stats.hops.load(Ordering::Relaxed), want.hops, "{which}");
        }
    }

    #[test]
    fn world_one_is_identity_with_zero_traffic() {
        let tree = TreeComm::new(1);
        let mut d = vec![3.0f32, -1.0];
        tree.all_reduce_mean(0, tags::LOSS, &mut d);
        assert_eq!(d, vec![3.0, -1.0]);
        assert_eq!(tree.stats.bytes.load(Ordering::Relaxed), 0);
        assert_eq!(tree.stats.rounds.load(Ordering::Relaxed), 1);
    }
}
