//! Ring collectives: bandwidth-optimal all-reduce as reduce-scatter +
//! all-gather over chunked segments (Baidu/NCCL-style).
//!
//! The buffer is split into `W` contiguous chunks (by default the same
//! [`crate::tensor::flat::shard_span`] segments the ZeRO stages shard
//! by; the `_spans` collective variants accept any rank-ordered
//! partition — the chunk ∩ shard case). In the reduce-scatter
//! phase, step `t` has every rank send one chunk to its successor and
//! receive one from its predecessor, folding its own contribution in —
//! after `W−1` steps each rank owns the fully-reduced chunk that is its
//! shard. The all-gather phase circulates the reduced chunks for another
//! `W−1` steps. Every link is busy every step, and each rank moves only
//! `2(W−1)/W` of the buffer per direction — the bandwidth-optimal
//! schedule, at the cost of `2(W−1)` serial hop latencies
//! (latency-*pessimal*; see [`super::TreeComm`] for the other extreme and
//! `memsim`'s `Interconnect` for the cost model that prices both).
//!
//! Bit-determinism: messages carry per-origin contributions
//! ([`super::p2p`]) and the rank that completes a chunk folds them in
//! rank order, so results are bit-identical to [`super::SharedMemComm`]
//! — while [`super::CommStats`] charges exactly the chunk bytes the real
//! algorithm would put on the wire at each hop.
//!
//! Ordering contract (stricter than the flat communicator, same as real
//! NCCL): hop receives block, so two ranks must not issue collectives
//! for *different* tags in opposite orders **on single threads**.
//! Concurrent issuance on separate threads (the worker pool) is fine —
//! messages are tag-matched — and every schedule arm in `exec`/`ddp`
//! issues inline collectives in deterministic unit order, while pool
//! jobs are submitted and dequeued in the same FIFO order on every rank,
//! which is exactly the progress guarantee the induction in the pool
//! docs needs.

use super::p2p::{Acct, Mailbox, MsgKey, Payload};
use super::{assert_spans_tile, mean_in_rank_order, CommStats, Communicator};
use crate::tensor::flat::shard_partition;
use std::sync::Arc;
use std::time::Instant;

/// Ring [`Communicator`]: reduce-scatter + all-gather over chunked
/// segments, 2(W−1) steps per all-reduce.
pub struct RingComm {
    world: usize,
    mail: Mailbox,
    stats: Arc<CommStats>,
}

impl RingComm {
    /// A ring communicator for `world` ranks.
    pub fn new(world: usize) -> Self {
        Self::with_stats(world, Arc::new(CommStats::default()))
    }

    /// [`RingComm::new`] recording into an externally shared
    /// [`CommStats`] (mixed-algorithm sessions).
    pub fn with_stats(world: usize, stats: Arc<CommStats>) -> Self {
        assert!(world > 0, "communicator needs at least one rank");
        Self { world, mail: Mailbox::new(world), stats }
    }

    /// Span of ring-chunk `k` under the ownership partition `spans`.
    /// Ring-chunk `k` finishes its reduction on rank `(k − 1) mod W`, so
    /// mapping it to span `(k − 1) mod W` makes every rank finish
    /// holding exactly the span it owns — the alignment the ZeRO update
    /// path relies on. `spans` is the balanced `shard_partition` for the
    /// plain collectives and the chunk ∩ shard intersections for the
    /// chunked ZeRO path.
    fn chunk_span(&self, spans: &[(usize, usize)], ring_chunk: usize) -> (usize, usize) {
        spans[(ring_chunk + self.world - 1) % self.world]
    }

    /// The reduce-scatter phase: `W−1` send/receive steps, after which
    /// this rank holds every rank's contribution for ring-chunk
    /// `(rank + 1) mod W` (= the span it owns).
    fn reduce_scatter_phase(
        &self,
        rank: usize,
        tag: u64,
        seq: u64,
        data: &[f32],
        spans: &[(usize, usize)],
        acct: &mut Acct,
    ) -> Payload {
        let w = self.world;
        let next = (rank + 1) % w;
        let prev = (rank + w - 1) % w;
        let chunk_of = |k: usize| {
            let (o, l) = self.chunk_span(spans, k);
            data[o..o + l].to_vec()
        };
        let mut carry: Payload = vec![(rank, chunk_of(rank))];
        for t in 0..w - 1 {
            let c_send = (rank + w - t) % w;
            let (_, send_len) = self.chunk_span(spans, c_send);
            self.mail.post(
                MsgKey { tag, seq, leg: t as u32, from: rank, to: next },
                std::mem::take(&mut carry),
            );
            acct.sent += 4 * send_len;
            acct.legs += 1;
            let c_recv = (rank + w - t - 1) % w;
            let (_, recv_len) = self.chunk_span(spans, c_recv);
            let mut incoming =
                self.mail.take(MsgKey { tag, seq, leg: t as u32, from: prev, to: rank });
            incoming.push((rank, chunk_of(c_recv)));
            acct.received += 4 * recv_len;
            acct.legs += 1;
            carry = incoming;
        }
        carry
    }

    /// The all-gather phase: circulate completed chunks for `W−1` steps.
    /// `have` is indexed by ring-chunk id and must hold this rank's own
    /// chunk (`(rank + 1) mod W`) on entry; on return it holds all `W`.
    #[allow(clippy::too_many_arguments)]
    fn all_gather_phase(
        &self,
        rank: usize,
        tag: u64,
        seq: u64,
        spans: &[(usize, usize)],
        leg0: u32,
        have: &mut [Option<Vec<f32>>],
        acct: &mut Acct,
    ) {
        let w = self.world;
        let next = (rank + 1) % w;
        let prev = (rank + w - 1) % w;
        for t in 0..w - 1 {
            let c_send = (rank + 1 + w - t) % w;
            let payload = have[c_send].clone().expect("all-gather invariant: chunk in hand");
            let (_, send_len) = self.chunk_span(spans, c_send);
            self.mail.post(
                MsgKey { tag, seq, leg: leg0 + t as u32, from: rank, to: next },
                vec![(c_send, payload)],
            );
            acct.sent += 4 * send_len;
            acct.legs += 1;
            let c_recv = (rank + w - t) % w;
            let (_, recv_len) = self.chunk_span(spans, c_recv);
            let mut msg =
                self.mail.take(MsgKey { tag, seq, leg: leg0 + t as u32, from: prev, to: rank });
            let (cid, chunk) = msg.pop().expect("all-gather payload");
            assert_eq!(cid, c_recv, "ring all-gather chunk id mismatch");
            have[c_recv] = Some(chunk);
            acct.received += 4 * recv_len;
            acct.legs += 1;
        }
    }
}

impl Communicator for RingComm {
    fn world(&self) -> usize {
        self.world
    }

    fn all_reduce_mean(&self, rank: usize, tag: u64, data: &mut [f32]) {
        let t0 = Instant::now();
        let w = self.world;
        assert!(rank < w, "rank {rank} out of range");
        if w == 1 {
            // mean over one rank is the identity; nothing moves
            self.stats.record(0, 0, 0, t0);
            return;
        }
        let seq = self.mail.next_seq(rank, tag);
        let mut acct = Acct::default();
        let n = data.len();
        let spans = shard_partition(n, w);
        let carry = self.reduce_scatter_phase(rank, tag, seq, data, &spans, &mut acct);
        let own = (rank + 1) % w;
        let (_, own_len) = self.chunk_span(&spans, own);
        let mut have: Vec<Option<Vec<f32>>> = (0..w).map(|_| None).collect();
        have[own] = Some(mean_in_rank_order(w, own_len, &carry));
        self.all_gather_phase(rank, tag, seq, &spans, (w - 1) as u32, &mut have, &mut acct);
        for (k, chunk) in have.iter().enumerate() {
            let (o, l) = self.chunk_span(&spans, k);
            data[o..o + l].copy_from_slice(chunk.as_ref().expect("all chunks gathered"));
        }
        self.stats.record(acct.sent, acct.received, acct.legs, t0);
    }

    fn reduce_scatter_mean_spans(
        &self,
        rank: usize,
        tag: u64,
        data: &mut [f32],
        spans: &[(usize, usize)],
    ) {
        let t0 = Instant::now();
        let w = self.world;
        assert!(rank < w, "rank {rank} out of range");
        assert_spans_tile(spans, w, data.len());
        if w == 1 {
            self.stats.record(0, 0, 0, t0);
            return;
        }
        let seq = self.mail.next_seq(rank, tag);
        let mut acct = Acct::default();
        let carry = self.reduce_scatter_phase(rank, tag, seq, data, spans, &mut acct);
        let own = (rank + 1) % w;
        // ring-chunk (rank + 1) maps exactly to this rank's span
        let (o, l) = self.chunk_span(spans, own);
        data[o..o + l].copy_from_slice(&mean_in_rank_order(w, l, &carry));
        self.stats.record(acct.sent, acct.received, acct.legs, t0);
    }

    fn all_gather_spans(&self, rank: usize, tag: u64, data: &mut [f32], spans: &[(usize, usize)]) {
        let t0 = Instant::now();
        let w = self.world;
        assert!(rank < w, "rank {rank} out of range");
        assert_spans_tile(spans, w, data.len());
        if w == 1 {
            self.stats.record(0, 0, 0, t0);
            return;
        }
        let seq = self.mail.next_seq(rank, tag);
        let mut acct = Acct::default();
        let own = (rank + 1) % w;
        let mut have: Vec<Option<Vec<f32>>> = (0..w).map(|_| None).collect();
        {
            let (o, l) = self.chunk_span(spans, own);
            have[own] = Some(data[o..o + l].to_vec());
        }
        self.all_gather_phase(rank, tag, seq, spans, 0, &mut have, &mut acct);
        for (k, chunk) in have.iter().enumerate() {
            let (o, l) = self.chunk_span(spans, k);
            data[o..o + l].copy_from_slice(chunk.as_ref().expect("all chunks gathered"));
        }
        self.stats.record(acct.sent, acct.received, acct.legs, t0);
    }

    fn stats(&self) -> &CommStats {
        &self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::super::algo::{
        wire_all_gather, wire_all_reduce, wire_reduce_scatter, CommAlgo, Topology,
    };
    use super::super::{tags, SharedMemComm};
    use super::*;
    use std::sync::atomic::Ordering;
    use std::sync::{Arc, Mutex};

    /// Drive one collective on every rank of both a ring and a flat
    /// communicator with identical inputs; return (ring, flat) outputs.
    fn drive(
        world: usize,
        n: usize,
        op: impl Fn(&dyn Communicator, usize, &mut [f32]) + Sync,
    ) -> (Vec<Vec<f32>>, Vec<Vec<f32>>) {
        let ring = Arc::new(RingComm::new(world));
        let flat = Arc::new(SharedMemComm::new(world));
        let outs = Arc::new(Mutex::new(vec![(Vec::new(), Vec::new()); world]));
        let op = &op;
        std::thread::scope(|s| {
            for rank in 0..world {
                let ring = Arc::clone(&ring);
                let flat = Arc::clone(&flat);
                let outs = Arc::clone(&outs);
                s.spawn(move || {
                    let base: Vec<f32> =
                        (0..n).map(|i| (i as f32 + 1.3) * (rank as f32 - 0.7)).collect();
                    let mut r = base.clone();
                    op(ring.as_ref(), rank, &mut r);
                    let mut f = base.clone();
                    op(flat.as_ref(), rank, &mut f);
                    outs.lock().unwrap()[rank] = (r, f);
                });
            }
        });
        let outs = outs.lock().unwrap();
        let ring_outs = outs.iter().map(|(r, _)| r.clone()).collect();
        let flat_outs = outs.iter().map(|(_, f)| f.clone()).collect();
        (ring_outs, flat_outs)
    }

    fn assert_bit_equal(a: &[Vec<f32>], b: &[Vec<f32>], what: &str) {
        for (rank, (x, y)) in a.iter().zip(b.iter()).enumerate() {
            assert_eq!(x.len(), y.len());
            for (i, (u, v)) in x.iter().zip(y.iter()).enumerate() {
                assert_eq!(u.to_bits(), v.to_bits(), "{what}: rank {rank} elem {i}: {u} vs {v}");
            }
        }
    }

    #[test]
    fn all_reduce_bit_identical_to_flat_at_every_world_size() {
        for world in [1usize, 2, 3, 4, 5] {
            // n = 10 is not divisible by most world sizes: chunks differ
            let (ring, flat) =
                drive(world, 10, |c, rank, d| c.all_reduce_mean(rank, tags::grad(0), d));
            assert_bit_equal(&ring, &flat, &format!("all_reduce world {world}"));
        }
    }

    #[test]
    fn reduce_scatter_bit_identical_to_flat() {
        for world in [2usize, 3, 4] {
            let (ring, flat) =
                drive(world, 11, |c, rank, d| c.reduce_scatter_mean(rank, tags::grad(1), d));
            assert_bit_equal(&ring, &flat, &format!("reduce_scatter world {world}"));
        }
    }

    #[test]
    fn all_gather_bit_identical_to_flat() {
        for world in [2usize, 3, 4] {
            // all_gather reads only the caller's own shard, so identical
            // inputs give identical reassembled outputs
            let (ring, flat) = drive(world, 9, |c, rank, d| c.all_gather(rank, tags::value(0), d));
            assert_bit_equal(&ring, &flat, &format!("all_gather world {world}"));
        }
    }

    /// Satellite accounting check: one ring all-reduce of n elements
    /// among W ranks moves exactly 2(W−1) chunk-sized messages per rank
    /// (counted at both endpoints) over 4(W−1) legs per rank.
    #[test]
    fn stats_match_closed_form() {
        for (world, n) in [(2usize, 8usize), (3, 10), (4, 10)] {
            let ring = Arc::new(RingComm::new(world));
            std::thread::scope(|s| {
                for rank in 0..world {
                    let ring = Arc::clone(&ring);
                    s.spawn(move || {
                        let mut d = vec![rank as f32; n];
                        ring.all_reduce_mean(rank, tags::grad(7), &mut d);
                    });
                }
            });
            let want = wire_all_reduce(CommAlgo::Ring, n, &Topology::flat(world));
            assert_eq!(ring.stats.bytes.load(Ordering::Relaxed), want.bytes, "w={world} n={n}");
            assert_eq!(ring.stats.hops.load(Ordering::Relaxed), want.hops, "w={world} n={n}");
            assert_eq!(ring.stats.rounds.load(Ordering::Relaxed), world as u64);
            // and the documented algebraic forms
            assert_eq!(want.bytes, 16 * n as u64 * (world as u64 - 1));
            assert_eq!(want.hops, 4 * world as u64 * (world as u64 - 1));
        }
    }

    #[test]
    fn phase_stats_match_closed_forms() {
        let world = 3;
        let n = 10;
        for (which, want) in [
            ("rs", wire_reduce_scatter(CommAlgo::Ring, n, &Topology::flat(world))),
            ("ag", wire_all_gather(CommAlgo::Ring, n, &Topology::flat(world))),
        ] {
            let ring = Arc::new(RingComm::new(world));
            std::thread::scope(|s| {
                for rank in 0..world {
                    let ring = Arc::clone(&ring);
                    s.spawn(move || {
                        let mut d = vec![1.0f32; n];
                        if which == "rs" {
                            ring.reduce_scatter_mean(rank, tags::grad(0), &mut d);
                        } else {
                            ring.all_gather(rank, tags::value(0), &mut d);
                        }
                    });
                }
            });
            assert_eq!(ring.stats.bytes.load(Ordering::Relaxed), want.bytes, "{which}");
            assert_eq!(ring.stats.hops.load(Ordering::Relaxed), want.hops, "{which}");
            // one phase: half of an all-reduce
            assert_eq!(want.bytes, 8 * n as u64 * (world as u64 - 1), "{which}");
        }
    }

    #[test]
    fn world_one_is_identity_with_zero_traffic() {
        let ring = RingComm::new(1);
        let mut d = vec![3.0f32, -1.0];
        ring.all_reduce_mean(0, tags::LOSS, &mut d);
        assert_eq!(d, vec![3.0, -1.0]);
        assert_eq!(ring.stats.bytes.load(Ordering::Relaxed), 0);
        assert_eq!(ring.stats.hops.load(Ordering::Relaxed), 0);
        assert_eq!(ring.stats.rounds.load(Ordering::Relaxed), 1);
    }

    /// Pool-overlap precondition: a rank may have ring collectives for
    /// several tags in flight at once on different worker threads, and
    /// they pair up by tag however the threads interleave (the executor's
    /// backward-fusion pool does exactly this).
    #[test]
    fn tags_decouple_concurrent_ring_sessions() {
        let world = 2;
        let ring = Arc::new(RingComm::new(world));
        let outs = Arc::new(Mutex::new([[0.0f32; 2]; 2]));
        std::thread::scope(|s| {
            for rank in 0..world {
                for (slot, tag) in [tags::grad(7), tags::grad(8)].into_iter().enumerate() {
                    let ring = Arc::clone(&ring);
                    let outs = Arc::clone(&outs);
                    s.spawn(move || {
                        let base = if slot == 0 { rank as f32 } else { 10.0 + rank as f32 };
                        let mut d = [base, base];
                        ring.all_reduce_mean(rank, tag, &mut d);
                        outs.lock().unwrap()[rank][slot] = d[0];
                    });
                }
            }
        });
        let outs = outs.lock().unwrap();
        for rank in 0..world {
            assert_eq!(outs[rank][0], 0.5, "mean of 0, 1");
            assert_eq!(outs[rank][1], 10.5, "mean of 10, 11");
        }
    }
}
