//! Collective-algorithm selection and the closed-form wire accounting
//! shared between the real communicators and `memsim`'s interconnect
//! cost model.
//!
//! Every [`crate::comm::Communicator`] implementation records its actual
//! per-hop traffic into [`crate::comm::CommStats`]; the `wire_*`
//! functions here are the closed forms of exactly that accounting
//! (asserted equal in each implementation's tests). `memsim` prices
//! collectives from the same functions, which is what lets
//! `rust/tests/integration_comm_model.rs` demand that the performance
//! model's per-collective bytes × hops match the measured stats
//! **exactly**, not approximately.
//!
//! Accounting semantics (per collective over `n` f32 elements, world W,
//! B = 4n payload bytes):
//!
//! | algo | all-reduce bytes | all-reduce hops | critical path |
//! |------|------------------|-----------------|---------------|
//! | flat | `2BW` (each rank stages B in, B out) | `2W` | 2 legs + root-serialized volume |
//! | ring | `4B(W−1)` (2(W−1) steps × W chunk messages, both ends) | `4W(W−1)` | `2(W−1)` hops of `B/W` |
//! | tree | `4B(W−1)` (2(W−1) full-size messages, both ends) | `4(W−1)` | `2⌈log₂W⌉` hops of `B` |
//!
//! `bytes` counts sent + received at both endpoints; `hops` counts
//! point-to-point legs (one per endpoint per message; the flat session's
//! contribute/collect pair counts as 2 per rank). Ring and tree move the
//! same total volume — the difference the cost model prices is *where*
//! it moves: the ring spreads it over every link in parallel, the tree
//! serializes full buffers over `O(log W)` links.

use super::ring::RingComm;
use super::tree::TreeComm;
use super::{Communicator, SharedMemComm};
use crate::tensor::flat::shard_partition;
use std::sync::Arc;

/// Which collective algorithm a DDP run (or a memsim prediction) uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CommAlgo {
    /// One staged session per collective ([`SharedMemComm`]): every rank
    /// contributes its full buffer and collects the full result.
    Flat,
    /// Reduce-scatter + all-gather over chunked segments
    /// ([`RingComm`]): bandwidth-optimal, `2(W−1)` hop latencies.
    Ring,
    /// Binomial reduce + broadcast ([`TreeComm`]): latency-optimal,
    /// `2⌈log₂W⌉` full-buffer hops.
    Tree,
}

impl CommAlgo {
    /// All algorithms, in presentation order.
    pub const ALL: [CommAlgo; 3] = [CommAlgo::Flat, CommAlgo::Ring, CommAlgo::Tree];

    /// Stable identifier used by CLI flags and bench tables.
    pub fn label(&self) -> &'static str {
        match self {
            CommAlgo::Flat => "flat",
            CommAlgo::Ring => "ring",
            CommAlgo::Tree => "tree",
        }
    }
}

impl std::str::FromStr for CommAlgo {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "flat" | "shared" => Ok(CommAlgo::Flat),
            "ring" => Ok(CommAlgo::Ring),
            "tree" => Ok(CommAlgo::Tree),
            _ => Err(format!("unknown collective algorithm '{s}' (flat, ring, tree)")),
        }
    }
}

/// Build the communicator implementing `algo` for `world` ranks.
pub fn make_comm(algo: CommAlgo, world: usize) -> Arc<dyn Communicator> {
    match algo {
        CommAlgo::Flat => Arc::new(SharedMemComm::new(world)),
        CommAlgo::Ring => Arc::new(RingComm::new(world)),
        CommAlgo::Tree => Arc::new(TreeComm::new(world)),
    }
}

/// Wire accounting of one collective, summed over all ranks — the exact
/// closed form of what the matching [`Communicator`] records into
/// [`crate::comm::CommStats`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WireCost {
    /// Bytes counted at both endpoints (sent + received).
    pub bytes: u64,
    /// Point-to-point legs (one per endpoint per message).
    pub hops: u64,
}

impl std::ops::AddAssign for WireCost {
    fn add_assign(&mut self, rhs: Self) {
        self.bytes += rhs.bytes;
        self.hops += rhs.hops;
    }
}

/// Closed-form wire cost of one `all_reduce_mean` of `n` f32 elements.
pub fn wire_all_reduce(algo: CommAlgo, n: usize, world: usize) -> WireCost {
    let (n64, w) = (n as u64, world as u64);
    match algo {
        // every rank stages 4n in and 4n out of the session, 2 legs each
        CommAlgo::Flat => WireCost { bytes: 8 * n64 * w, hops: 2 * w },
        CommAlgo::Ring => {
            if world == 1 {
                return WireCost::default();
            }
            // per step the W chunk messages tile the buffer exactly, so
            // each of the 2(W−1) steps moves 4n sent + 4n received
            WireCost { bytes: 16 * n64 * (w - 1), hops: 4 * w * (w - 1) }
        }
        CommAlgo::Tree => {
            if world == 1 {
                return WireCost::default();
            }
            // 2(W−1) full-size messages (reduce + broadcast edges)
            WireCost { bytes: 16 * n64 * (w - 1), hops: 4 * (w - 1) }
        }
    }
}

/// Closed-form wire cost of one `reduce_scatter_mean` (balanced
/// [`crate::tensor::flat::shard_span`] ownership).
pub fn wire_reduce_scatter(algo: CommAlgo, n: usize, world: usize) -> WireCost {
    wire_reduce_scatter_spans(algo, &shard_partition(n, world))
}

/// Closed-form wire cost of one `reduce_scatter_mean_spans` over an
/// explicit rank-ordered ownership partition (the chunked ZeRO path).
/// Flat and ring traffic depend only on the total length — the spans
/// tile the buffer, so per-stage message sets always cover it exactly —
/// while the tree's root scatter star moves every *non-root* span, so
/// its byte count shifts with `spans[0]`.
pub fn wire_reduce_scatter_spans(algo: CommAlgo, spans: &[(usize, usize)]) -> WireCost {
    let world = spans.len();
    let n: usize = spans.iter().map(|s| s.1).sum();
    let (n64, w) = (n as u64, world as u64);
    match algo {
        // each rank stages 4n in and takes its 4·span out
        CommAlgo::Flat => WireCost { bytes: 4 * n64 * w + 4 * n64, hops: 2 * w },
        CommAlgo::Ring => {
            if world == 1 {
                return WireCost::default();
            }
            WireCost { bytes: 8 * n64 * (w - 1), hops: 2 * w * (w - 1) }
        }
        CommAlgo::Tree => {
            if world == 1 {
                return WireCost::default();
            }
            // W−1 full-size reduce messages + the root's span scatter
            let nonroot = 4 * (n - spans[0].1) as u64;
            WireCost { bytes: 8 * n64 * (w - 1) + 2 * nonroot, hops: 4 * (w - 1) }
        }
    }
}

/// Closed-form wire cost of one `all_gather` (balanced ownership).
pub fn wire_all_gather(algo: CommAlgo, n: usize, world: usize) -> WireCost {
    wire_all_gather_spans(algo, &shard_partition(n, world))
}

/// Closed-form wire cost of one `all_gather_spans` over an explicit
/// rank-ordered ownership partition (see
/// [`wire_reduce_scatter_spans`] for why only the tree depends on the
/// span shape).
pub fn wire_all_gather_spans(algo: CommAlgo, spans: &[(usize, usize)]) -> WireCost {
    let world = spans.len();
    let n: usize = spans.iter().map(|s| s.1).sum();
    let (n64, w) = (n as u64, world as u64);
    match algo {
        // each rank stages its 4·span in and takes 4n out
        CommAlgo::Flat => WireCost { bytes: 4 * n64 + 4 * n64 * w, hops: 2 * w },
        CommAlgo::Ring => {
            if world == 1 {
                return WireCost::default();
            }
            WireCost { bytes: 8 * n64 * (w - 1), hops: 2 * w * (w - 1) }
        }
        CommAlgo::Tree => {
            if world == 1 {
                return WireCost::default();
            }
            // span star-gather to the root + W−1 full-size broadcasts
            let nonroot = 4 * (n - spans[0].1) as u64;
            WireCost { bytes: 2 * nonroot + 8 * n64 * (w - 1), hops: 4 * (w - 1) }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::Ordering;

    #[test]
    fn parse_and_label_roundtrip() {
        for algo in CommAlgo::ALL {
            assert_eq!(algo.label().parse::<CommAlgo>().unwrap(), algo);
        }
        assert!("mesh".parse::<CommAlgo>().is_err());
    }

    #[test]
    fn make_comm_builds_the_right_world() {
        for algo in CommAlgo::ALL {
            assert_eq!(make_comm(algo, 3).world(), 3);
        }
    }

    /// The flat closed form must match what `SharedMemComm` has always
    /// recorded (8n bytes and 2 legs per rank per all-reduce).
    #[test]
    fn flat_closed_form_matches_recorded_stats() {
        use super::super::tags;
        use std::sync::Arc;
        let world = 3;
        let n = 10;
        let comm = Arc::new(SharedMemComm::new(world));
        std::thread::scope(|s| {
            for rank in 0..world {
                let comm = Arc::clone(&comm);
                s.spawn(move || {
                    let mut d = vec![rank as f32; n];
                    comm.all_reduce_mean(rank, tags::grad(0), &mut d);
                });
            }
        });
        let want = wire_all_reduce(CommAlgo::Flat, n, world);
        assert_eq!(comm.stats().bytes.load(Ordering::Relaxed), want.bytes);
        assert_eq!(comm.stats().hops.load(Ordering::Relaxed), want.hops);
        assert_eq!(want.bytes, 8 * n as u64 * world as u64);
        assert_eq!(want.hops, 2 * world as u64);
    }

    #[test]
    fn ring_and_tree_move_equal_volume_over_different_hop_counts() {
        let (n, w) = (1000, 8);
        let ring = wire_all_reduce(CommAlgo::Ring, n, w);
        let tree = wire_all_reduce(CommAlgo::Tree, n, w);
        assert_eq!(ring.bytes, tree.bytes, "same total volume");
        assert!(ring.hops > tree.hops, "ring pays W× the hops");
        assert_eq!(ring.hops, 4 * 8 * 7);
        assert_eq!(tree.hops, 4 * 7);
    }

    #[test]
    fn world_one_moves_nothing_for_ring_and_tree() {
        for op in [wire_all_reduce, wire_reduce_scatter, wire_all_gather] {
            assert_eq!(op(CommAlgo::Ring, 64, 1), WireCost::default());
            assert_eq!(op(CommAlgo::Tree, 64, 1), WireCost::default());
        }
    }

    /// Span-parameterized collectives must record exactly the span-aware
    /// closed forms, for every algorithm, on an unbalanced partition
    /// (chunk ∩ shard shapes) — including an empty span.
    #[test]
    fn span_closed_forms_match_recorded_stats() {
        use super::super::{make_comm, tags};
        let world = 3;
        let spans = [(0usize, 4usize), (4, 0), (4, 3)];
        let n = 7;
        for algo in CommAlgo::ALL {
            let comm = make_comm(algo, world);
            let c = &comm;
            std::thread::scope(|s| {
                for rank in 0..world {
                    s.spawn(move || {
                        let mut d = vec![rank as f32; n];
                        c.reduce_scatter_mean_spans(rank, tags::grad(0), &mut d, &spans);
                        let mut d = vec![1.0f32; n];
                        c.all_gather_spans(rank, tags::value(0), &mut d, &spans);
                    });
                }
            });
            let want_rs = wire_reduce_scatter_spans(algo, &spans);
            let want_ag = wire_all_gather_spans(algo, &spans);
            assert_eq!(
                comm.stats().bytes.load(Ordering::Relaxed),
                want_rs.bytes + want_ag.bytes,
                "{} span bytes",
                algo.label()
            );
            assert_eq!(
                comm.stats().hops.load(Ordering::Relaxed),
                want_rs.hops + want_ag.hops,
                "{} span hops",
                algo.label()
            );
        }
        // balanced spans reduce to the historical closed forms
        for algo in CommAlgo::ALL {
            assert_eq!(
                wire_reduce_scatter_spans(algo, &crate::tensor::flat::shard_partition(10, 4)),
                wire_reduce_scatter(algo, 10, 4)
            );
        }
    }

    #[test]
    fn wire_cost_accumulates() {
        let mut acc = WireCost::default();
        acc += wire_all_reduce(CommAlgo::Ring, 10, 4);
        acc += wire_all_reduce(CommAlgo::Ring, 10, 4);
        assert_eq!(acc.bytes, 2 * wire_all_reduce(CommAlgo::Ring, 10, 4).bytes);
    }
}
