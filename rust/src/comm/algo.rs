//! Collective-algorithm selection, the two-tier [`Topology`] model, and
//! the closed-form wire accounting shared between the real communicators
//! and `memsim`'s interconnect cost model.
//!
//! Every [`crate::comm::Communicator`] implementation records its actual
//! per-hop traffic into [`crate::comm::CommStats`]; the `wire_*`
//! functions here are the closed forms of exactly that accounting
//! (asserted equal in each implementation's tests). `memsim` prices
//! collectives from the same functions, which is what lets
//! `rust/tests/integration_comm_model.rs` and
//! `rust/tests/integration_hier_plan.rs` demand that the performance
//! model's per-collective bytes × hops match the measured stats
//! **exactly**, not approximately.
//!
//! Accounting semantics (per collective over `n` f32 elements, world W,
//! B = 4n payload bytes):
//!
//! | algo | all-reduce bytes | all-reduce hops | critical path |
//! |------|------------------|-----------------|---------------|
//! | flat | `2BW` (each rank stages B in, B out) | `2W` | 2 legs + root-serialized volume |
//! | ring | `4B(W−1)` (2(W−1) steps × W chunk messages, both ends) | `4W(W−1)` | `2(W−1)` hops of `B/W` |
//! | tree | `4B(W−1)` (2(W−1) full-size messages, both ends) | `4(W−1)` | `2⌈log₂W⌉` hops of `B` |
//! | hier | per-node ring phases + leader stars + a leader tree | see [`wire_all_reduce`] | intra ring + `2⌈log₂N⌉` inter hops |
//!
//! `bytes` counts sent + received at both endpoints; `hops` counts
//! point-to-point legs (one per endpoint per message; the flat session's
//! contribute/collect pair counts as 2 per rank). Flat, ring, and tree
//! are *topology-oblivious*: their traffic depends only on `W`, so once
//! a world spans nodes every one of their legs may cross the slow
//! inter-node link. [`CommAlgo::Hier`] is the topology-aware
//! composition — ring reduce-scatter / all-gather *within* each node,
//! a binomial tree *across* node leaders — whose closed forms here are
//! written as the same per-message loops the implementation charges, so
//! the match is structural, not algebraic.

use super::hier::HierComm;
use super::ring::RingComm;
use super::tree::TreeComm;
use super::{CommStats, Communicator, SharedMemComm};
use crate::tensor::flat::shard_partition;
use std::sync::Arc;

/// The two-tier replica layout of a collective group: `world` ranks
/// packed into nodes of `ranks_per_node` consecutive ranks (the last
/// node may be smaller when the division is ragged). `ranks_per_node ==
/// 0` is the degenerate one-tier case — every rank on one node — which
/// is what the flat presets and all pre-existing call sites use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Topology {
    /// Number of ranks in the group.
    pub world: usize,
    /// Consecutive ranks per node; 0 means "all ranks on one node".
    pub ranks_per_node: usize,
}

impl Topology {
    /// One-tier topology: every rank on a single node.
    pub fn flat(world: usize) -> Self {
        Self { world, ranks_per_node: 0 }
    }

    /// Two-tier topology with `ranks_per_node` consecutive ranks per
    /// node (the last node takes the remainder).
    pub fn two_tier(world: usize, ranks_per_node: usize) -> Self {
        assert!(ranks_per_node > 0, "two_tier: ranks_per_node must be positive");
        Self { world, ranks_per_node }
    }

    /// Effective node capacity (the one-tier case reports the world).
    pub fn rpn(&self) -> usize {
        if self.ranks_per_node == 0 {
            self.world.max(1)
        } else {
            self.ranks_per_node
        }
    }

    /// Number of nodes (≥ 1).
    pub fn nodes(&self) -> usize {
        let (w, r) = (self.world.max(1), self.rpn());
        (w + r - 1) / r
    }

    /// Node index of `rank`.
    pub fn node_of(&self, rank: usize) -> usize {
        rank / self.rpn()
    }

    /// First (leader) rank of node `g`.
    pub fn node_first(&self, g: usize) -> usize {
        g * self.rpn()
    }

    /// Number of ranks on node `g` (the last node may be smaller).
    pub fn node_size(&self, g: usize) -> usize {
        let first = self.node_first(g);
        self.rpn().min(self.world - first)
    }

    /// True when the group spans more than one node.
    pub fn multi_node(&self) -> bool {
        self.nodes() > 1
    }

    /// Display label: `flat` for one-tier, `RxN` for two-tier.
    pub fn label(&self) -> String {
        if self.ranks_per_node == 0 {
            "flat".to_string()
        } else {
            format!("{}x{}", self.rpn(), self.nodes())
        }
    }

    /// Parse a `--topology` value for a group of `world` ranks: `flat`
    /// (one tier) or `RxN` (R consecutive ranks per node, N nodes). The
    /// node grid must cover the world: `R·(N−1) < world ≤ R·N`.
    pub fn parse(s: &str, world: usize) -> Result<Self, String> {
        if s == "flat" {
            return Ok(Self::flat(world));
        }
        let (r, nn) = s
            .split_once('x')
            .ok_or_else(|| format!("topology '{s}' is not 'flat' or 'RxN'"))?;
        let r: usize = r.parse().map_err(|_| format!("bad ranks-per-node in '{s}'"))?;
        let nn: usize = nn.parse().map_err(|_| format!("bad node count in '{s}'"))?;
        if r == 0 || nn == 0 {
            return Err(format!("topology '{s}' must have positive dimensions"));
        }
        let topo = Self::two_tier(world, r);
        if topo.nodes() != nn {
            return Err(format!(
                "topology {r}x{nn} does not cover world {world} \
                 (need {}x{} for this world)",
                r,
                topo.nodes()
            ));
        }
        Ok(topo)
    }
}

/// Which collective algorithm a DDP run (or a memsim prediction) uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CommAlgo {
    /// One staged session per collective ([`SharedMemComm`]): every rank
    /// contributes its full buffer and collects the full result.
    Flat,
    /// Reduce-scatter + all-gather over chunked segments
    /// ([`RingComm`]): bandwidth-optimal, `2(W−1)` hop latencies.
    Ring,
    /// Binomial reduce + broadcast ([`TreeComm`]): latency-optimal,
    /// `2⌈log₂W⌉` full-buffer hops.
    Tree,
    /// Two-tier composition ([`HierComm`]): ring reduce-scatter /
    /// all-gather within each node, binomial tree across node leaders.
    /// Degenerates to the leader tree at one rank per node; the only
    /// algorithm whose wire shape follows the [`Topology`].
    Hier,
}

impl CommAlgo {
    /// All algorithms, in presentation order.
    pub const ALL: [CommAlgo; 4] =
        [CommAlgo::Flat, CommAlgo::Ring, CommAlgo::Tree, CommAlgo::Hier];

    /// The topology-oblivious algorithms (wire shape independent of the
    /// node grid) — the historical one-tier set.
    pub const ONE_TIER: [CommAlgo; 3] = [CommAlgo::Flat, CommAlgo::Ring, CommAlgo::Tree];

    /// Stable identifier used by CLI flags and bench tables.
    pub fn label(&self) -> &'static str {
        match self {
            CommAlgo::Flat => "flat",
            CommAlgo::Ring => "ring",
            CommAlgo::Tree => "tree",
            CommAlgo::Hier => "hier",
        }
    }
}

impl std::str::FromStr for CommAlgo {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "flat" | "shared" => Ok(CommAlgo::Flat),
            "ring" => Ok(CommAlgo::Ring),
            "tree" => Ok(CommAlgo::Tree),
            "hier" => Ok(CommAlgo::Hier),
            _ => Err(format!("unknown collective algorithm '{s}' (flat, ring, tree, hier)")),
        }
    }
}

/// What `DdpConfig::algo` / `--algo` selects: one algorithm for every
/// collective, or the per-bucket planner ([`crate::comm::plan`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AlgoSelect {
    /// Every collective uses this algorithm.
    Fixed(CommAlgo),
    /// `--algo auto`: a memsim-driven plan picks the algorithm (and the
    /// chunk split) per bucket; collectives route through
    /// [`crate::comm::plan::MixedComm`].
    Auto,
}

impl AlgoSelect {
    /// Stable identifier used by CLI flags and bench tables.
    pub fn label(&self) -> &'static str {
        match self {
            AlgoSelect::Fixed(a) => a.label(),
            AlgoSelect::Auto => "auto",
        }
    }
}

impl From<CommAlgo> for AlgoSelect {
    fn from(a: CommAlgo) -> Self {
        AlgoSelect::Fixed(a)
    }
}

impl std::str::FromStr for AlgoSelect {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        if s == "auto" {
            return Ok(AlgoSelect::Auto);
        }
        s.parse::<CommAlgo>()
            .map(AlgoSelect::Fixed)
            .map_err(|e| format!("{e} — or 'auto' for the per-bucket planner"))
    }
}

/// Build the communicator implementing `algo` over `topo` (the one-tier
/// algorithms only read `topo.world`).
pub fn make_comm(algo: CommAlgo, topo: &Topology) -> Arc<dyn Communicator> {
    make_comm_shared(algo, topo, Arc::new(CommStats::default()))
}

/// [`make_comm`] with an externally shared [`CommStats`] — how
/// [`crate::comm::plan::MixedComm`] keeps one accounting path across a
/// mixed-algorithm session.
pub fn make_comm_shared(
    algo: CommAlgo,
    topo: &Topology,
    stats: Arc<CommStats>,
) -> Arc<dyn Communicator> {
    match algo {
        CommAlgo::Flat => Arc::new(SharedMemComm::with_stats(topo.world, stats)),
        CommAlgo::Ring => Arc::new(RingComm::with_stats(topo.world, stats)),
        CommAlgo::Tree => Arc::new(TreeComm::with_stats(topo.world, stats)),
        CommAlgo::Hier => Arc::new(HierComm::with_stats(*topo, stats)),
    }
}

/// Wire accounting of one collective, summed over all ranks — the exact
/// closed form of what the matching [`Communicator`] records into
/// [`crate::comm::CommStats`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WireCost {
    /// Bytes counted at both endpoints (sent + received).
    pub bytes: u64,
    /// Point-to-point legs (one per endpoint per message).
    pub hops: u64,
}

impl WireCost {
    fn msg(&mut self, elems: usize) {
        self.bytes += 8 * elems as u64;
        self.hops += 2;
    }

    /// Reprice this (f32-sized) cost at a different wire element width:
    /// `bytes × elem_bytes / 4`, hops unchanged. Exact — every closed-form
    /// byte term is a multiple of 4 per element, mirroring the rescaling
    /// [`crate::comm::CommStats::set_elem_bytes`] applies to the measured
    /// side, so measured == closed form holds under every dtype.
    pub fn scaled_to(self, elem_bytes: usize) -> WireCost {
        WireCost { bytes: self.bytes * elem_bytes as u64 / 4, hops: self.hops }
    }
}

impl std::ops::AddAssign for WireCost {
    fn add_assign(&mut self, rhs: Self) {
        self.bytes += rhs.bytes;
        self.hops += rhs.hops;
    }
}

/// The intra-node ring phases of [`CommAlgo::Hier`], charged per
/// message exactly as `HierComm` does: `phases` ring sweeps (reduce-
/// scatter and/or all-gather) of `s − 1` steps each, every step moving
/// one chunk message per node member (the chunks tile the buffer).
fn hier_ring_phase(c: &mut WireCost, n: usize, s: usize, phases: usize) {
    if s <= 1 {
        return;
    }
    let spans = shard_partition(n, s);
    for _phase in 0..phases {
        for _step in 0..s - 1 {
            for span in &spans {
                c.msg(span.1);
            }
        }
    }
}

/// One leader star of [`CommAlgo::Hier`]: a message per non-leader node
/// member carrying that member's span (gather up or scatter down).
fn hier_star(c: &mut WireCost, spans: &[(usize, usize)]) {
    for span in spans.iter().skip(1) {
        c.msg(span.1);
    }
}

/// The chunk tiling of the inter-node tree payload when `HierComm`
/// pipelines chunks through the binomial tree: `[0, n)` cut into runs
/// of `chunk` elements (last run smaller), capped at 1024 runs so the
/// per-chunk leg tags fit their namespace. `chunk == 0` (or `≥ n`)
/// means whole-payload messages — the unchunked legacy shape. Shared
/// verbatim by [`HierComm`]'s message loop and the `wire_*` closed
/// forms, so the accounting match stays structural.
pub(crate) fn inter_chunk_spans(n: usize, chunk: usize) -> Vec<(usize, usize)> {
    if chunk == 0 || chunk >= n || n == 0 {
        return vec![(0, n)];
    }
    let chunk = chunk.max((n + 1023) / 1024);
    let mut out = Vec::new();
    let mut off = 0;
    while off < n {
        let len = chunk.min(n - off);
        out.push((off, len));
        off += len;
    }
    out
}

/// The inter-node binomial tree of [`CommAlgo::Hier`]: `N − 1` edges
/// per direction (reduce and/or broadcast), each moving the full
/// payload — as one message, or pipelined as [`inter_chunk_spans`]
/// chunk messages (same bytes, `chunks×` the legs).
fn hier_tree(c: &mut WireCost, n: usize, nodes: usize, directions: usize, chunk: usize) {
    let chunks = inter_chunk_spans(n, chunk);
    for _dir in 0..directions {
        for _edge in 0..nodes - 1 {
            for (_, len) in &chunks {
                c.msg(*len);
            }
        }
    }
}

/// Contiguous region of `spans` owned by node `g` of `topo` (the spans
/// are per-rank and rank-ordered, so a node's union is contiguous).
fn node_region(topo: &Topology, spans: &[(usize, usize)], g: usize) -> (usize, usize) {
    let first = topo.node_first(g);
    let s = topo.node_size(g);
    let off = spans[first].0;
    let len: usize = spans[first..first + s].iter().map(|x| x.1).sum();
    (off, len)
}

/// Closed-form wire cost of one `all_reduce_mean` of `n` f32 elements.
pub fn wire_all_reduce(algo: CommAlgo, n: usize, topo: &Topology) -> WireCost {
    wire_all_reduce_chunked(algo, n, topo, 0)
}

/// [`wire_all_reduce`] with the hier inter-node tree pipelined in
/// chunks of `inter_chunk` elements (0: whole-payload messages — the
/// other algorithms ignore the parameter). Chunking never changes the
/// byte count, only the leg count: each tree edge's one full-size
/// message becomes [`inter_chunk_spans`]`.len()` chunk messages.
pub fn wire_all_reduce_chunked(
    algo: CommAlgo,
    n: usize,
    topo: &Topology,
    inter_chunk: usize,
) -> WireCost {
    let world = topo.world;
    let (n64, w) = (n as u64, world as u64);
    match algo {
        // every rank stages 4n in and 4n out of the session, 2 legs each
        CommAlgo::Flat => WireCost { bytes: 8 * n64 * w, hops: 2 * w },
        CommAlgo::Ring => {
            if world == 1 {
                return WireCost::default();
            }
            // per step the W chunk messages tile the buffer exactly, so
            // each of the 2(W−1) steps moves 4n sent + 4n received
            WireCost { bytes: 16 * n64 * (w - 1), hops: 4 * w * (w - 1) }
        }
        CommAlgo::Tree => {
            if world == 1 {
                return WireCost::default();
            }
            // 2(W−1) full-size messages (reduce + broadcast edges)
            WireCost { bytes: 16 * n64 * (w - 1), hops: 4 * (w - 1) }
        }
        CommAlgo::Hier => {
            if world == 1 {
                return WireCost::default();
            }
            let mut c = WireCost::default();
            for g in 0..topo.nodes() {
                let s = topo.node_size(g);
                if s > 1 {
                    let local = shard_partition(n, s);
                    hier_ring_phase(&mut c, n, s, 1); // intra ring RS
                    hier_star(&mut c, &local); // span gather to leader
                    hier_star(&mut c, &local); // result span scatter
                    hier_ring_phase(&mut c, n, s, 1); // intra ring AG
                }
            }
            if topo.multi_node() {
                hier_tree(&mut c, n, topo.nodes(), 2, inter_chunk); // reduce + bcast
            }
            c
        }
    }
}

/// Closed-form wire cost of one `reduce_scatter_mean` (balanced
/// [`crate::tensor::flat::shard_span`] ownership).
pub fn wire_reduce_scatter(algo: CommAlgo, n: usize, topo: &Topology) -> WireCost {
    wire_reduce_scatter_spans(algo, &shard_partition(n, topo.world), topo)
}

/// [`wire_reduce_scatter_spans`] with the hier inter-node tree
/// pipelined in `inter_chunk`-element chunks (see
/// [`wire_all_reduce_chunked`]).
pub fn wire_reduce_scatter_spans_chunked(
    algo: CommAlgo,
    spans: &[(usize, usize)],
    topo: &Topology,
    inter_chunk: usize,
) -> WireCost {
    wire_rs_spans_impl(algo, spans, topo, inter_chunk)
}

/// Closed-form wire cost of one `reduce_scatter_mean_spans` over an
/// explicit rank-ordered ownership partition (the chunked ZeRO path).
/// Flat and ring traffic depend only on the total length — the spans
/// tile the buffer, so per-stage message sets always cover it exactly —
/// while the tree's root scatter star moves every *non-root* span (its
/// byte count shifts with `spans[0]`) and the hierarchical down path
/// moves node regions then member spans.
pub fn wire_reduce_scatter_spans(
    algo: CommAlgo,
    spans: &[(usize, usize)],
    topo: &Topology,
) -> WireCost {
    wire_rs_spans_impl(algo, spans, topo, 0)
}

fn wire_rs_spans_impl(
    algo: CommAlgo,
    spans: &[(usize, usize)],
    topo: &Topology,
    inter_chunk: usize,
) -> WireCost {
    let world = spans.len();
    debug_assert_eq!(world, topo.world, "span count must match the topology world");
    let n: usize = spans.iter().map(|s| s.1).sum();
    let (n64, w) = (n as u64, world as u64);
    match algo {
        // each rank stages 4n in and takes its 4·span out
        CommAlgo::Flat => WireCost { bytes: 4 * n64 * w + 4 * n64, hops: 2 * w },
        CommAlgo::Ring => {
            if world == 1 {
                return WireCost::default();
            }
            WireCost { bytes: 8 * n64 * (w - 1), hops: 2 * w * (w - 1) }
        }
        CommAlgo::Tree => {
            if world == 1 {
                return WireCost::default();
            }
            // W−1 full-size reduce messages + the root's span scatter
            let nonroot = 4 * (n - spans[0].1) as u64;
            WireCost { bytes: 8 * n64 * (w - 1) + 2 * nonroot, hops: 4 * (w - 1) }
        }
        CommAlgo::Hier => {
            if world == 1 {
                return WireCost::default();
            }
            let mut c = WireCost::default();
            // up path: intra ring RS over local spans, span gather to
            // the leader, leader tree-reduce to the root
            for g in 0..topo.nodes() {
                let s = topo.node_size(g);
                if s > 1 {
                    hier_ring_phase(&mut c, n, s, 1);
                    hier_star(&mut c, &shard_partition(n, s));
                }
            }
            if topo.multi_node() {
                hier_tree(&mut c, n, topo.nodes(), 1, inter_chunk); // reduce only
                // root scatters each non-root leader its node's region
                for g in 1..topo.nodes() {
                    c.msg(node_region(topo, spans, g).1);
                }
            }
            // leaders scatter each member its owned span
            for g in 0..topo.nodes() {
                let first = topo.node_first(g);
                for r in first + 1..first + topo.node_size(g) {
                    c.msg(spans[r].1);
                }
            }
            c
        }
    }
}

/// Closed-form wire cost of one `all_gather` (balanced ownership).
pub fn wire_all_gather(algo: CommAlgo, n: usize, topo: &Topology) -> WireCost {
    wire_all_gather_spans(algo, &shard_partition(n, topo.world), topo)
}

/// Closed-form wire cost of one `all_gather_spans` over an explicit
/// rank-ordered ownership partition (see
/// [`wire_reduce_scatter_spans`] for why only the tree and hier shapes
/// depend on the span layout).
pub fn wire_all_gather_spans(
    algo: CommAlgo,
    spans: &[(usize, usize)],
    topo: &Topology,
) -> WireCost {
    wire_ag_spans_impl(algo, spans, topo, 0)
}

/// [`wire_all_gather_spans`] with the hier inter-node tree pipelined in
/// `inter_chunk`-element chunks (see [`wire_all_reduce_chunked`]).
pub fn wire_all_gather_spans_chunked(
    algo: CommAlgo,
    spans: &[(usize, usize)],
    topo: &Topology,
    inter_chunk: usize,
) -> WireCost {
    wire_ag_spans_impl(algo, spans, topo, inter_chunk)
}

fn wire_ag_spans_impl(
    algo: CommAlgo,
    spans: &[(usize, usize)],
    topo: &Topology,
    inter_chunk: usize,
) -> WireCost {
    let world = spans.len();
    debug_assert_eq!(world, topo.world, "span count must match the topology world");
    let n: usize = spans.iter().map(|s| s.1).sum();
    let (n64, w) = (n as u64, world as u64);
    match algo {
        // each rank stages its 4·span in and takes 4n out
        CommAlgo::Flat => WireCost { bytes: 4 * n64 + 4 * n64 * w, hops: 2 * w },
        CommAlgo::Ring => {
            if world == 1 {
                return WireCost::default();
            }
            WireCost { bytes: 8 * n64 * (w - 1), hops: 2 * w * (w - 1) }
        }
        CommAlgo::Tree => {
            if world == 1 {
                return WireCost::default();
            }
            // span star-gather to the root + W−1 full-size broadcasts
            let nonroot = 4 * (n - spans[0].1) as u64;
            WireCost { bytes: 2 * nonroot + 8 * n64 * (w - 1), hops: 4 * (w - 1) }
        }
        CommAlgo::Hier => {
            if world == 1 {
                return WireCost::default();
            }
            let mut c = WireCost::default();
            // up path: members star their owned spans to the leader,
            // non-root leaders star their regions to the root
            for g in 0..topo.nodes() {
                let first = topo.node_first(g);
                for r in first + 1..first + topo.node_size(g) {
                    c.msg(spans[r].1);
                }
            }
            if topo.multi_node() {
                for g in 1..topo.nodes() {
                    c.msg(node_region(topo, spans, g).1);
                }
                hier_tree(&mut c, n, topo.nodes(), 1, inter_chunk); // full broadcast
            }
            // down path within each node: local-span scatter + ring AG
            for g in 0..topo.nodes() {
                let s = topo.node_size(g);
                if s > 1 {
                    hier_star(&mut c, &shard_partition(n, s));
                    hier_ring_phase(&mut c, n, s, 1);
                }
            }
            c
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::Ordering;

    #[test]
    fn parse_and_label_roundtrip() {
        for algo in CommAlgo::ALL {
            assert_eq!(algo.label().parse::<CommAlgo>().unwrap(), algo);
        }
        assert!("mesh".parse::<CommAlgo>().is_err());
        assert_eq!("auto".parse::<AlgoSelect>().unwrap(), AlgoSelect::Auto);
        assert_eq!(
            "ring".parse::<AlgoSelect>().unwrap(),
            AlgoSelect::Fixed(CommAlgo::Ring)
        );
        assert_eq!(AlgoSelect::Auto.label(), "auto");
        assert_eq!(AlgoSelect::from(CommAlgo::Tree).label(), "tree");
    }

    #[test]
    fn topology_grid_covers_ragged_worlds() {
        let t = Topology::two_tier(5, 2);
        assert_eq!(t.nodes(), 3);
        assert_eq!(t.node_size(0), 2);
        assert_eq!(t.node_size(2), 1);
        assert_eq!(t.node_of(4), 2);
        assert_eq!(t.node_first(1), 2);
        assert!(t.multi_node());
        assert_eq!(t.label(), "2x3");
        let f = Topology::flat(4);
        assert_eq!(f.nodes(), 1);
        assert_eq!(f.rpn(), 4);
        assert!(!f.multi_node());
        assert_eq!(f.label(), "flat");
    }

    #[test]
    fn topology_parse_checks_world_coverage() {
        assert_eq!(Topology::parse("flat", 4).unwrap(), Topology::flat(4));
        assert_eq!(Topology::parse("2x2", 4).unwrap(), Topology::two_tier(4, 2));
        assert_eq!(Topology::parse("2x3", 5).unwrap(), Topology::two_tier(5, 2));
        assert!(Topology::parse("2x2", 5).is_err());
        assert!(Topology::parse("0x2", 4).is_err());
        assert!(Topology::parse("junk", 4).is_err());
    }

    #[test]
    fn make_comm_builds_the_right_world() {
        for algo in CommAlgo::ALL {
            assert_eq!(make_comm(algo, &Topology::two_tier(3, 2)).world(), 3);
        }
    }

    /// The flat closed form must match what `SharedMemComm` has always
    /// recorded (8n bytes and 2 legs per rank per all-reduce).
    #[test]
    fn flat_closed_form_matches_recorded_stats() {
        use super::super::tags;
        use std::sync::Arc;
        let world = 3;
        let n = 10;
        let comm = Arc::new(SharedMemComm::new(world));
        std::thread::scope(|s| {
            for rank in 0..world {
                let comm = Arc::clone(&comm);
                s.spawn(move || {
                    let mut d = vec![rank as f32; n];
                    comm.all_reduce_mean(rank, tags::grad(0), &mut d);
                });
            }
        });
        let want = wire_all_reduce(CommAlgo::Flat, n, &Topology::flat(world));
        assert_eq!(comm.stats().bytes.load(Ordering::Relaxed), want.bytes);
        assert_eq!(comm.stats().hops.load(Ordering::Relaxed), want.hops);
        assert_eq!(want.bytes, 8 * n as u64 * world as u64);
        assert_eq!(want.hops, 2 * world as u64);
    }

    #[test]
    fn ring_and_tree_move_equal_volume_over_different_hop_counts() {
        let (n, w) = (1000, 8);
        let topo = Topology::flat(w);
        let ring = wire_all_reduce(CommAlgo::Ring, n, &topo);
        let tree = wire_all_reduce(CommAlgo::Tree, n, &topo);
        assert_eq!(ring.bytes, tree.bytes, "same total volume");
        assert!(ring.hops > tree.hops, "ring pays W× the hops");
        assert_eq!(ring.hops, 4 * 8 * 7);
        assert_eq!(tree.hops, 4 * 7);
    }

    #[test]
    fn world_one_moves_nothing_for_ring_tree_and_hier() {
        let topo = Topology::flat(1);
        for op in [wire_all_reduce, wire_reduce_scatter, wire_all_gather] {
            assert_eq!(op(CommAlgo::Ring, 64, &topo), WireCost::default());
            assert_eq!(op(CommAlgo::Tree, 64, &topo), WireCost::default());
            assert_eq!(op(CommAlgo::Hier, 64, &topo), WireCost::default());
        }
    }

    /// With one rank per node the hierarchical composition has no intra
    /// traffic and its wire shape collapses to the leader tree exactly —
    /// for the all-reduce and for both span-parameterized halves.
    #[test]
    fn hier_degenerates_to_tree_at_one_rank_per_node() {
        for w in [2usize, 3, 4, 5] {
            let solo = Topology::two_tier(w, 1);
            let flat = Topology::flat(w);
            let n = 10;
            assert_eq!(
                wire_all_reduce(CommAlgo::Hier, n, &solo),
                wire_all_reduce(CommAlgo::Tree, n, &flat),
                "world {w} all-reduce"
            );
            let spans = shard_partition(n, w);
            assert_eq!(
                wire_reduce_scatter_spans(CommAlgo::Hier, &spans, &solo),
                wire_reduce_scatter_spans(CommAlgo::Tree, &spans, &flat),
                "world {w} reduce-scatter"
            );
            assert_eq!(
                wire_all_gather_spans(CommAlgo::Hier, &spans, &solo),
                wire_all_gather_spans(CommAlgo::Tree, &spans, &flat),
                "world {w} all-gather"
            );
        }
    }

    /// Hand-checked two-tier all-reduce arithmetic: world 4 as 2×2.
    /// Per node (s = 2, n = 10): ring RS 8n, ring AG 8n, gather star
    /// 8·5, scatter star 8·5 → 240 bytes; ×2 nodes = 480. Inter tree:
    /// 16n(N−1) = 160. Hops: per node 2s(s−1)·2 + 2(s−1)·2 = 12; ×2 =
    /// 24; inter 4(N−1) = 4.
    #[test]
    fn hier_two_by_two_closed_form_by_hand() {
        let topo = Topology::two_tier(4, 2);
        let c = wire_all_reduce(CommAlgo::Hier, 10, &topo);
        assert_eq!(c.bytes, 480 + 160);
        assert_eq!(c.hops, 24 + 4);
    }

    /// Span-parameterized collectives must record exactly the span-aware
    /// closed forms, for every algorithm, on an unbalanced partition
    /// (chunk ∩ shard shapes) — including an empty span.
    #[test]
    fn span_closed_forms_match_recorded_stats() {
        use super::super::{make_comm, tags};
        let world = 3;
        let spans = [(0usize, 4usize), (4, 0), (4, 3)];
        let n = 7;
        for (algo, topo) in [
            (CommAlgo::Flat, Topology::flat(world)),
            (CommAlgo::Ring, Topology::flat(world)),
            (CommAlgo::Tree, Topology::flat(world)),
            (CommAlgo::Hier, Topology::two_tier(world, 2)),
        ] {
            let comm = make_comm(algo, &topo);
            let c = &comm;
            std::thread::scope(|s| {
                for rank in 0..world {
                    s.spawn(move || {
                        let mut d = vec![rank as f32; n];
                        c.reduce_scatter_mean_spans(rank, tags::grad(0), &mut d, &spans);
                        let mut d = vec![1.0f32; n];
                        c.all_gather_spans(rank, tags::value(0), &mut d, &spans);
                    });
                }
            });
            let want_rs = wire_reduce_scatter_spans(algo, &spans, &topo);
            let want_ag = wire_all_gather_spans(algo, &spans, &topo);
            assert_eq!(
                comm.stats().bytes.load(Ordering::Relaxed),
                want_rs.bytes + want_ag.bytes,
                "{} span bytes",
                algo.label()
            );
            assert_eq!(
                comm.stats().hops.load(Ordering::Relaxed),
                want_rs.hops + want_ag.hops,
                "{} span hops",
                algo.label()
            );
        }
        // balanced spans reduce to the historical closed forms
        let topo = Topology::flat(4);
        for algo in CommAlgo::ALL {
            assert_eq!(
                wire_reduce_scatter_spans(
                    algo,
                    &crate::tensor::flat::shard_partition(10, 4),
                    &topo
                ),
                wire_reduce_scatter(algo, 10, &topo)
            );
        }
    }

    #[test]
    fn wire_cost_accumulates() {
        let topo = Topology::flat(4);
        let mut acc = WireCost::default();
        acc += wire_all_reduce(CommAlgo::Ring, 10, &topo);
        acc += wire_all_reduce(CommAlgo::Ring, 10, &topo);
        assert_eq!(acc.bytes, 2 * wire_all_reduce(CommAlgo::Ring, 10, &topo).bytes);
    }
}
