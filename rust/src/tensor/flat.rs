//! Flat-buffer layout: pack many logically-separate tensors into one
//! contiguous 1-D backing [`Tensor`], addressed through per-member spans.
//!
//! This is the storage substrate for [`crate::optim::bucket`]: a bucket's
//! gradients and optimizer state each live in one backing tensor laid out
//! by a [`FlatLayout`], so a multi-parameter optimizer update (or a DDP
//! all-reduce) streams over a single allocation instead of hopping
//! between per-parameter heap blocks — the locality argument of Bagua's
//! `FusedOptimizer` and IPEX optimizer fusion, applied to this engine.

use super::Tensor;

/// One member's region inside a flat backing buffer.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Span {
    /// Element offset of the region's start in the backing buffer.
    pub offset: usize,
    /// Region length in elements.
    pub len: usize,
    /// Logical shape of the member (product equals `len`).
    pub shape: Vec<usize>,
}

/// The contiguous region of a flat buffer of `len` elements owned by
/// `rank` out of `world`, as `(offset, length)`. Shards tile the buffer
/// in rank order with no gaps; when `len` does not divide evenly the
/// remainder goes one element each to the lowest ranks, so shard sizes
/// differ by at most one (a rank's shard may be empty when
/// `len < world`). This is the single source of shard-span truth shared
/// by the ZeRO-1 update path ([`crate::optim::bucket`]) and the
/// communicator's reduce-scatter / all-gather
/// ([`crate::comm::Communicator`]).
pub fn shard_span(len: usize, world: usize, rank: usize) -> (usize, usize) {
    assert!(world > 0, "shard_span: world must be positive");
    assert!(rank < world, "shard_span: rank {rank} out of {world}");
    let base = len / world;
    let rem = len % world;
    let offset = rank * base + rank.min(rem);
    let size = base + usize::from(rank < rem);
    (offset, size)
}

/// All `world` shard spans of a buffer of `len` elements, in rank order —
/// the balanced partition every collective defaults to. The spans tile
/// `[0, len)` contiguously (see [`shard_span`]).
pub fn shard_partition(len: usize, world: usize) -> Vec<(usize, usize)> {
    (0..world).map(|rank| shard_span(len, world, rank)).collect()
}

/// The contiguous region of a buffer of `len` elements owned by `rank`
/// under *node-local* placement over a `world`-rank grid with
/// `ranks_per_node` ranks per node: the buffer is first partitioned
/// over the nodes (balanced, like [`shard_span`] over node indices),
/// then each node's region over its local ranks. A rank's span
/// therefore never straddles a node boundary, so a cross-node gather of
/// the full buffer moves each node's region across its uplink exactly
/// once — the Xu et al. 2020 cross-replica sharding layout. With
/// `ranks_per_node == 0` (flat topology) or a single node this is
/// exactly [`shard_span`]; spans always tile `[0, len)` in rank order.
pub fn node_local_span(
    len: usize,
    world: usize,
    ranks_per_node: usize,
    rank: usize,
) -> (usize, usize) {
    assert!(world > 0, "node_local_span: world must be positive");
    assert!(rank < world, "node_local_span: rank {rank} out of {world}");
    if ranks_per_node == 0 || ranks_per_node >= world {
        return shard_span(len, world, rank);
    }
    // node grid arithmetic mirrors `comm::algo::Topology` exactly
    let nodes = (world + ranks_per_node - 1) / ranks_per_node;
    let g = rank / ranks_per_node;
    let first = g * ranks_per_node;
    let size = ranks_per_node.min(world - first);
    let (region_off, region_len) = shard_span(len, nodes, g);
    let (local_off, local_len) = shard_span(region_len, size, rank - first);
    (region_off + local_off, local_len)
}

/// All `world` node-local placement spans (see [`node_local_span`]), in
/// rank order — the ownership partition the ZeRO paths hand to the
/// span collectives on a two-tier topology.
pub fn node_local_spans(len: usize, world: usize, ranks_per_node: usize) -> Vec<(usize, usize)> {
    (0..world).map(|rank| node_local_span(len, world, ranks_per_node, rank)).collect()
}

/// Clamp a rank-ordered tiling partition of some arena to the chunk
/// `[chunk_off, chunk_off + chunk_len)` and rebase to chunk-local
/// coordinates. Because the input spans tile the arena, the clamped
/// spans tile the chunk in rank order — ranks whose span misses the
/// chunk get a correctly placed *empty* span at the boundary,
/// satisfying the span-collective tiling contract
/// ([`crate::comm::Communicator`]'s `_spans` methods).
pub fn clamp_spans_to_chunk(
    spans: &[(usize, usize)],
    chunk_off: usize,
    chunk_len: usize,
) -> Vec<(usize, usize)> {
    spans
        .iter()
        .map(|&(so, sl)| {
            let lo = so.clamp(chunk_off, chunk_off + chunk_len);
            let hi = (so + sl).clamp(chunk_off, chunk_off + chunk_len);
            (lo - chunk_off, hi - lo)
        })
        .collect()
}

/// The chunk × shard ownership arithmetic of the chunked ZeRO
/// collectives: each rank's bucket-level [`shard_span`] of a `total`
/// -element arena, clamped to the chunk via [`clamp_spans_to_chunk`].
pub fn chunk_shard_spans(
    total: usize,
    world: usize,
    chunk_off: usize,
    chunk_len: usize,
) -> Vec<(usize, usize)> {
    clamp_spans_to_chunk(&shard_partition(total, world), chunk_off, chunk_len)
}

/// A contiguous packing of N member shapes: spans are tight (no padding)
/// and ordered, so walking members in index order walks the backing
/// buffer front to back exactly once.
#[derive(Debug, Clone, Default)]
pub struct FlatLayout {
    spans: Vec<Span>,
    total: usize,
}

impl FlatLayout {
    /// Build a tight layout packing `shapes` in order.
    pub fn from_shapes(shapes: &[&[usize]]) -> Self {
        let mut spans = Vec::with_capacity(shapes.len());
        let mut offset = 0;
        for shape in shapes {
            let len: usize = shape.iter().product();
            spans.push(Span { offset, len, shape: shape.to_vec() });
            offset += len;
        }
        Self { spans, total: offset }
    }

    /// Number of members.
    pub fn len(&self) -> usize {
        self.spans.len()
    }

    /// True when the layout has no members.
    pub fn is_empty(&self) -> bool {
        self.spans.is_empty()
    }

    /// Total element count of the backing buffer.
    pub fn total(&self) -> usize {
        self.total
    }

    /// Span of member `i`.
    pub fn span(&self, i: usize) -> &Span {
        &self.spans[i]
    }

    /// All spans in member order.
    pub fn spans(&self) -> &[Span] {
        &self.spans
    }

    /// Allocate a zeroed 1-D backing tensor for this layout.
    pub fn alloc(&self) -> Tensor {
        Tensor::zeros(&[self.total])
    }

    /// Borrow member `i`'s region of a backing tensor.
    pub fn slice<'a>(&self, flat: &'a Tensor, i: usize) -> &'a [f32] {
        let s = &self.spans[i];
        &flat.data()[s.offset..s.offset + s.len]
    }

    /// Mutably borrow member `i`'s region of a backing tensor.
    pub fn slice_mut<'a>(&self, flat: &'a mut Tensor, i: usize) -> &'a mut [f32] {
        let s = &self.spans[i];
        &mut flat.data_mut()[s.offset..s.offset + s.len]
    }

    /// Materialize member `i` as an owned tensor with its logical shape
    /// (a copy — the backing buffer stays authoritative).
    pub fn view(&self, flat: &Tensor, i: usize) -> Tensor {
        let s = &self.spans[i];
        Tensor::from_vec(&s.shape, self.slice(flat, i).to_vec())
    }

    /// Overwrite member `i`'s region from `src` (lengths must match).
    pub fn write(&self, flat: &mut Tensor, i: usize, src: &Tensor) {
        let dst = self.slice_mut(flat, i);
        assert_eq!(dst.len(), src.len(), "flat write: member {i} length mismatch");
        dst.copy_from_slice(src.data());
    }

    /// Pack `tensors` (matching this layout) into a fresh backing tensor.
    pub fn pack(&self, tensors: &[&Tensor]) -> Tensor {
        assert_eq!(tensors.len(), self.spans.len(), "flat pack: member count");
        let mut flat = self.alloc();
        for (i, t) in tensors.iter().enumerate() {
            self.write(&mut flat, i, t);
        }
        flat
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layout() -> FlatLayout {
        FlatLayout::from_shapes(&[&[2, 3], &[4], &[1, 1, 2]])
    }

    #[test]
    fn spans_are_tight_and_ordered() {
        let l = layout();
        assert_eq!(l.len(), 3);
        assert_eq!(l.total(), 6 + 4 + 2);
        assert_eq!(l.span(0).offset, 0);
        assert_eq!(l.span(1).offset, 6);
        assert_eq!(l.span(2).offset, 10);
        assert_eq!(l.span(2).shape, vec![1, 1, 2]);
        assert!(!l.is_empty());
        assert!(FlatLayout::from_shapes(&[]).is_empty());
    }

    #[test]
    fn pack_view_roundtrip() {
        let l = layout();
        let a = Tensor::from_vec(&[2, 3], (0..6).map(|i| i as f32).collect());
        let b = Tensor::full(&[4], 7.0);
        let c = Tensor::from_vec(&[1, 1, 2], vec![8.0, 9.0]);
        let flat = l.pack(&[&a, &b, &c]);
        assert_eq!(flat.shape(), &[12]);
        assert_eq!(l.view(&flat, 0), a);
        assert_eq!(l.view(&flat, 1), b);
        assert_eq!(l.view(&flat, 2), c);
    }

    #[test]
    fn slice_mut_edits_backing() {
        let l = layout();
        let mut flat = l.alloc();
        l.slice_mut(&mut flat, 1).fill(3.0);
        assert_eq!(flat.data()[5], 0.0);
        assert_eq!(flat.data()[6], 3.0);
        assert_eq!(flat.data()[9], 3.0);
        assert_eq!(flat.data()[10], 0.0);
        assert_eq!(l.slice(&flat, 1), &[3.0; 4]);
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn write_rejects_wrong_length() {
        let l = layout();
        let mut flat = l.alloc();
        l.write(&mut flat, 0, &Tensor::zeros(&[2]));
    }

    #[test]
    fn shard_spans_tile_the_buffer() {
        for (len, world) in [(12usize, 4usize), (10, 4), (3, 4), (0, 2), (7, 1), (5, 5)] {
            let mut next = 0usize;
            for rank in 0..world {
                let (off, sz) = shard_span(len, world, rank);
                assert_eq!(off, next, "len {len} world {world} rank {rank}: contiguous");
                next = off + sz;
                // balanced: sizes differ by at most one
                assert!(sz >= len / world && sz <= len / world + 1);
            }
            assert_eq!(next, len, "shards cover exactly the buffer");
        }
        // remainder goes to the lowest ranks
        assert_eq!(shard_span(10, 4, 0), (0, 3));
        assert_eq!(shard_span(10, 4, 1), (3, 3));
        assert_eq!(shard_span(10, 4, 2), (6, 2));
        assert_eq!(shard_span(10, 4, 3), (8, 2));
        // a rank can own nothing
        assert_eq!(shard_span(3, 4, 3), (3, 0));
    }

    #[test]
    fn shard_partition_matches_spans() {
        let p = shard_partition(10, 4);
        assert_eq!(p, vec![(0, 3), (3, 3), (6, 2), (8, 2)]);
        for (rank, span) in p.iter().enumerate() {
            assert_eq!(*span, shard_span(10, 4, rank));
        }
    }

    #[test]
    fn node_local_spans_tile_and_respect_node_boundaries() {
        // 10 elems, 4 ranks in nodes of 2: node regions [0,5) [5,10),
        // members split each region — vs balanced (0,3)(3,3)(6,2)(8,2)
        let p = node_local_spans(10, 4, 2);
        assert_eq!(p, vec![(0, 3), (3, 2), (5, 3), (8, 2)]);
        // flat (rpn 0) and single-node (rpn >= world) degenerate exactly
        assert_eq!(node_local_spans(10, 4, 0), shard_partition(10, 4));
        assert_eq!(node_local_spans(10, 4, 4), shard_partition(10, 4));
        assert_eq!(node_local_spans(10, 4, 7), shard_partition(10, 4));
        // ragged grid: 5 ranks in nodes of 2 → node sizes [2, 2, 1]
        let p = node_local_spans(11, 5, 2);
        assert_eq!(p, vec![(0, 2), (2, 2), (4, 2), (6, 2), (8, 3)]);
        // every grid tiles contiguously in rank order
        for (len, world, rpn) in [(10usize, 4usize, 2usize), (11, 5, 2), (3, 4, 2), (64, 6, 4)] {
            let mut next = 0;
            for (rank, (o, l)) in node_local_spans(len, world, rpn).iter().enumerate() {
                assert_eq!(*o, next, "len {len} {world}x{rpn} rank {rank}");
                assert_eq!((*o, *l), node_local_span(len, world, rpn, rank));
                next = o + l;
            }
            assert_eq!(next, len);
        }
    }

    #[test]
    fn clamp_spans_to_chunk_rebases_any_tiling() {
        // node-local placement ∩ chunk: same contract as the balanced
        // chunk_shard_spans, over the placed partition
        let placed = node_local_spans(10, 4, 2); // (0,3)(3,2)(5,3)(8,2)
        assert_eq!(clamp_spans_to_chunk(&placed, 4, 4), vec![(0, 0), (0, 1), (1, 3), (4, 0)]);
        let mut next = 0;
        for (o, l) in clamp_spans_to_chunk(&placed, 2, 7) {
            assert_eq!(o, next);
            next = o + l;
        }
        assert_eq!(next, 7);
    }

    #[test]
    fn chunk_shard_spans_tile_the_chunk_with_placed_empties() {
        // 12-element arena, world 3 (shards [0,4) [4,8) [8,12)), chunk
        // [3, 8): rank 0 owns [3,4), rank 1 owns [4,8), rank 2 nothing
        let spans = chunk_shard_spans(12, 3, 3, 5);
        assert_eq!(spans, vec![(0, 1), (1, 4), (5, 0)]);
        // chunk before rank 1's shard: the empty spans still sit at
        // their tiling positions (rank 1/2 empty at the chunk's end)
        let spans = chunk_shard_spans(12, 3, 0, 2);
        assert_eq!(spans, vec![(0, 2), (2, 0), (2, 0)]);
        // chunk after rank 0/1: empties at offset 0
        let spans = chunk_shard_spans(12, 3, 9, 3);
        assert_eq!(spans, vec![(0, 0), (0, 0), (0, 3)]);
        // every case tiles contiguously in rank order
        for (off, len) in [(3usize, 5usize), (0, 2), (9, 3), (0, 12), (5, 0)] {
            let mut next = 0;
            for (o, l) in chunk_shard_spans(12, 3, off, len) {
                assert_eq!(o, next);
                next = o + l;
            }
            assert_eq!(next, len, "chunk [{off}, {}) covered", off + len);
        }
    }
}
