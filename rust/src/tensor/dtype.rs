//! Arena element dtypes: FP32 and BF16 with FP32 master state.
//!
//! The bucketed storage layer keeps its arenas as `Vec<f32>` under
//! either dtype — BF16 is modelled by rounding every value to the
//! nearest bfloat16 (round-to-nearest-even on the top 16 bits) at the
//! points where a real BF16 arena would be written: gradient
//! accumulation, post-update value writes, and initial bucketization.
//! This gives bit-exact BF16 *numerics* (every stored value is
//! representable in bfloat16) while reusing the existing flat f32
//! layout, kernels, and collectives. Optimizer state stays FP32
//! master copies (the IPEX fused-update pattern), so only value/grad
//! arenas and wire bytes halve in the dtype-aware accounting
//! ([`Dtype::elem_bytes`]).

use std::str::FromStr;

/// Element dtype of the value/grad arenas. Optimizer state is always
/// FP32 master state regardless of this choice.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Dtype {
    /// 4-byte IEEE single precision — the bit-identical reference.
    #[default]
    F32,
    /// 2-byte bfloat16 arenas with FP32 master optimizer state.
    Bf16,
}

impl Dtype {
    /// Bytes per arena/wire element under this dtype.
    pub fn elem_bytes(self) -> usize {
        match self {
            Dtype::F32 => 4,
            Dtype::Bf16 => 2,
        }
    }

    /// CLI / report label.
    pub fn label(self) -> &'static str {
        match self {
            Dtype::F32 => "f32",
            Dtype::Bf16 => "bf16",
        }
    }

    /// Round one value to this dtype's storage precision. Identity for
    /// FP32; round-to-nearest-even bfloat16 for BF16.
    pub fn round(self, x: f32) -> f32 {
        match self {
            Dtype::F32 => x,
            Dtype::Bf16 => bf16_round(x),
        }
    }

    /// Round a slice in place to this dtype's storage precision.
    pub fn round_slice(self, xs: &mut [f32]) {
        if self == Dtype::Bf16 {
            for x in xs.iter_mut() {
                *x = bf16_round(*x);
            }
        }
    }
}

impl FromStr for Dtype {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "f32" | "fp32" => Ok(Dtype::F32),
            "bf16" | "bfloat16" => Ok(Dtype::Bf16),
            other => Err(format!("unknown dtype '{other}' (expected f32|bf16)")),
        }
    }
}

/// Round an f32 to the nearest bfloat16 (round-to-nearest-even) and
/// widen back. NaN payloads pass through with the quiet bit kept so a
/// NaN never rounds into infinity.
pub fn bf16_round(x: f32) -> f32 {
    let bits = x.to_bits();
    if x.is_nan() {
        // keep a canonical quiet NaN representable in bf16
        return f32::from_bits(bits | 0x0040_0000);
    }
    let rounded = bits.wrapping_add(0x7FFF + ((bits >> 16) & 1)) & 0xFFFF_0000;
    f32::from_bits(rounded)
}

/// Environment default for `--grad-elim`: `OPTFUSE_GRAD_ELIM` set to
/// `1`/`true`/`on` enables it. CLI flags override.
pub fn grad_elim_env_default() -> bool {
    matches!(
        std::env::var("OPTFUSE_GRAD_ELIM").ok().as_deref(),
        Some("1") | Some("true") | Some("on")
    )
}

/// Environment default for `--dtype`: `OPTFUSE_DTYPE=f32|bf16`.
/// Unset or unparsable falls back to FP32. CLI flags override.
pub fn dtype_env_default() -> Dtype {
    std::env::var("OPTFUSE_DTYPE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(Dtype::F32)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bf16_round_is_idempotent_and_representable() {
        for &x in &[0.0f32, -0.0, 1.0, -1.5, 3.14159, 1e-20, 1e20, 65504.0, 0.1] {
            let r = bf16_round(x);
            assert_eq!(bf16_round(r), r, "idempotent at {x}");
            assert_eq!(r.to_bits() & 0xFFFF, 0, "low mantissa clear at {x}");
        }
    }

    #[test]
    fn bf16_round_nearest_even() {
        // value exactly halfway between two bf16 neighbours rounds to even
        let lo = f32::from_bits(0x3F80_0000); // 1.0
        let hi = f32::from_bits(0x3F81_0000); // next bf16 up
        let mid = f32::from_bits(0x3F80_8000);
        assert_eq!(bf16_round(mid), lo, "ties to even (low bit 0)");
        let mid2 = f32::from_bits(0x3F81_8000);
        let hi2 = f32::from_bits(0x3F82_0000);
        assert_eq!(bf16_round(mid2), hi2, "ties to even (low bit 1)");
        assert!(bf16_round(f32::from_bits(0x3F80_8001)) == hi, "above tie rounds up");
    }

    #[test]
    fn bf16_round_handles_specials() {
        assert!(bf16_round(f32::NAN).is_nan());
        assert_eq!(bf16_round(f32::INFINITY), f32::INFINITY);
        assert_eq!(bf16_round(f32::NEG_INFINITY), f32::NEG_INFINITY);
        // large-but-finite must not overflow to inf unless it rounds there
        assert!(bf16_round(f32::MAX).is_infinite());
    }

    #[test]
    fn dtype_parse_and_bytes() {
        assert_eq!("f32".parse::<Dtype>().unwrap(), Dtype::F32);
        assert_eq!("bf16".parse::<Dtype>().unwrap(), Dtype::Bf16);
        assert!("f16".parse::<Dtype>().is_err());
        assert_eq!(Dtype::F32.elem_bytes(), 4);
        assert_eq!(Dtype::Bf16.elem_bytes(), 2);
    }
}
