//! Dense f32 host tensors. The compute representation is f32 (the
//! paper's experiments are single-precision, §C.1); shape is a small
//! Vec<usize> in row-major (C) order. The bucketed storage layer can
//! model BF16 arenas on top via [`dtype`] rounding.

pub mod dtype;
pub mod flat;

use crate::util::XorShiftRng;
use std::fmt;

/// A dense, row-major f32 tensor.
#[derive(Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}", self.shape)?;
        if self.data.len() <= 8 {
            write!(f, " {:?}", self.data)
        } else {
            write!(f, " [{} elems]", self.data.len())
        }
    }
}

impl Tensor {
    /// Zero-filled tensor.
    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Self { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    /// Constant-filled tensor.
    pub fn full(shape: &[usize], v: f32) -> Self {
        let n = shape.iter().product();
        Self { shape: shape.to_vec(), data: vec![v; n] }
    }

    /// Build from raw data; panics if the element count mismatches.
    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {shape:?} vs data len {}",
            data.len()
        );
        Self { shape: shape.to_vec(), data }
    }

    /// Gaussian init, N(0, std).
    pub fn randn(shape: &[usize], std: f32, rng: &mut XorShiftRng) -> Self {
        let mut t = Self::zeros(shape);
        rng.fill_normal(&mut t.data, std);
        t
    }

    /// Kaiming-ish fan-in init for a weight of shape [fan_in, fan_out] (or
    /// any shape where dim 0 is fan-in).
    pub fn kaiming(shape: &[usize], rng: &mut XorShiftRng) -> Self {
        let fan_in = shape.first().copied().unwrap_or(1).max(1);
        Self::randn(shape, (2.0 / fan_in as f32).sqrt(), rng)
    }

    #[inline]
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.data.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    #[inline]
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    #[inline]
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Reinterpret the buffer with a new shape of identical element count.
    pub fn reshape(&self, shape: &[usize]) -> Tensor {
        assert_eq!(
            shape.iter().product::<usize>(),
            self.data.len(),
            "reshape {:?} -> {shape:?}",
            self.shape
        );
        Tensor { shape: shape.to_vec(), data: self.data.clone() }
    }

    /// Number of rows when viewed as 2-D [rows, cols] (flattening leading
    /// dims). Panics on rank-0.
    pub fn rows_cols(&self) -> (usize, usize) {
        assert!(!self.shape.is_empty());
        let cols = *self.shape.last().unwrap();
        let rows = self.data.len() / cols.max(1);
        (rows, cols)
    }

    /// Elementwise binary op into a fresh tensor (shapes must match).
    pub fn zip(&self, other: &Tensor, f: impl Fn(f32, f32) -> f32) -> Tensor {
        assert_eq!(self.shape, other.shape, "zip shape mismatch");
        let data = self
            .data
            .iter()
            .zip(other.data.iter())
            .map(|(a, b)| f(*a, *b))
            .collect();
        Tensor { shape: self.shape.clone(), data }
    }

    /// Elementwise map into a fresh tensor.
    pub fn map(&self, f: impl Fn(f32) -> f32) -> Tensor {
        Tensor { shape: self.shape.clone(), data: self.data.iter().map(|x| f(*x)).collect() }
    }

    /// In-place axpy: self += alpha * other.
    pub fn axpy(&mut self, alpha: f32, other: &Tensor) {
        assert_eq!(self.shape, other.shape, "axpy shape mismatch");
        for (a, b) in self.data.iter_mut().zip(other.data.iter()) {
            *a += alpha * b;
        }
    }

    /// In-place scale.
    pub fn scale(&mut self, alpha: f32) {
        for a in self.data.iter_mut() {
            *a *= alpha;
        }
    }

    /// In-place zero.
    pub fn zero_(&mut self) {
        self.data.iter_mut().for_each(|x| *x = 0.0);
    }

    /// Sum of elements.
    pub fn sum(&self) -> f32 {
        self.data.iter().sum()
    }

    /// L2 norm.
    pub fn l2(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    /// Max |x|.
    pub fn linf(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, x| m.max(x.abs()))
    }

    /// Max elementwise |a-b| against another tensor.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(other.data.iter())
            .fold(0.0f32, |m, (a, b)| m.max((a - b).abs()))
    }

    /// True if all elements are finite.
    pub fn all_finite(&self) -> bool {
        self.data.iter().all(|x| x.is_finite())
    }

    /// Bytes occupied by the data buffer (for the memory simulator).
    pub fn nbytes(&self) -> u64 {
        (self.data.len() * std::mem::size_of::<f32>()) as u64
    }
}

/// Assert two tensors are elementwise close; panics with a diagnostic.
pub fn assert_close(a: &Tensor, b: &Tensor, atol: f32, rtol: f32, what: &str) {
    assert_eq!(a.shape(), b.shape(), "{what}: shape mismatch");
    for (i, (x, y)) in a.data().iter().zip(b.data().iter()).enumerate() {
        let tol = atol + rtol * y.abs().max(x.abs());
        assert!(
            (x - y).abs() <= tol,
            "{what}: elem {i}: {x} vs {y} (tol {tol})"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_shape() {
        let t = Tensor::zeros(&[2, 3]);
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.len(), 6);
        assert_eq!(t.rows_cols(), (2, 3));
        let u = Tensor::from_vec(&[3], vec![1.0, 2.0, 3.0]);
        assert_eq!(u.sum(), 6.0);
    }

    #[test]
    #[should_panic]
    fn from_vec_shape_mismatch_panics() {
        Tensor::from_vec(&[2, 2], vec![1.0]);
    }

    #[test]
    fn elementwise_ops() {
        let a = Tensor::from_vec(&[2], vec![1.0, 2.0]);
        let b = Tensor::from_vec(&[2], vec![3.0, 5.0]);
        assert_eq!(a.zip(&b, |x, y| x + y).data(), &[4.0, 7.0]);
        assert_eq!(a.map(|x| x * 2.0).data(), &[2.0, 4.0]);
        let mut c = a.clone();
        c.axpy(2.0, &b);
        assert_eq!(c.data(), &[7.0, 12.0]);
        c.scale(0.5);
        assert_eq!(c.data(), &[3.5, 6.0]);
        c.zero_();
        assert_eq!(c.data(), &[0.0, 0.0]);
    }

    #[test]
    fn norms() {
        let a = Tensor::from_vec(&[2], vec![3.0, -4.0]);
        assert_eq!(a.l2(), 5.0);
        assert_eq!(a.linf(), 4.0);
        assert!(a.all_finite());
        let b = Tensor::from_vec(&[1], vec![f32::NAN]);
        assert!(!b.all_finite());
    }

    #[test]
    fn reshape_preserves_data() {
        let a = Tensor::from_vec(&[2, 3], (0..6).map(|i| i as f32).collect());
        let b = a.reshape(&[3, 2]);
        assert_eq!(b.shape(), &[3, 2]);
        assert_eq!(b.data(), a.data());
    }

    #[test]
    fn randn_statistics() {
        let mut rng = XorShiftRng::new(5);
        let t = Tensor::randn(&[10_000], 2.0, &mut rng);
        let mean = t.sum() / t.len() as f32;
        assert!(mean.abs() < 0.1);
        let var = t.data().iter().map(|x| (x - mean).powi(2)).sum::<f32>() / t.len() as f32;
        assert!((var - 4.0).abs() < 0.4, "var {var}");
    }

    #[test]
    fn max_abs_diff_works() {
        let a = Tensor::from_vec(&[2], vec![1.0, 2.0]);
        let b = Tensor::from_vec(&[2], vec![1.5, 2.0]);
        assert_eq!(a.max_abs_diff(&b), 0.5);
    }
}
