//! Iterative optimizers (paper §A, Alg. 1). Each optimizer is a pure
//! per-parameter update rule: the *schedule* that decides **when** each
//! update runs lives in `exec/` — that separation is exactly what lets the
//! same optimizer run under baseline, forward-fusion, or backward-fusion
//! without changing its math (the paper's "plug-in" property).
//!
//! Per the paper's Fig. 2 memory model, the update also *resets the
//! gradient* — grads are "read and reset by the optimizer".
//!
//! Every rule is written once as a raw-slice kernel
//! ([`Optimizer::update_slices`]); the per-parameter entry point
//! ([`Optimizer::update`]) and the fused multi-parameter entry point
//! ([`Optimizer::update_bucket`], over [`bucket`] flat storage) are both
//! derived from it, so scattered and bucketed training are bit-identical
//! by construction.

pub mod bucket;
pub mod sched;

use crate::exec::kernel::{self, KernelConfig, KernelMode};
use crate::graph::ParamData;
use crate::tensor::Tensor;
use bucket::BucketViewMut;

/// Below this many elements the `simd-mt` update path skips the scoped-
/// thread fork and runs the single-threaded lane kernel instead.
const MT_MIN_ELEMS: usize = 4096;

/// Hyper-parameters shared across optimizers.
#[derive(Debug, Clone)]
pub struct Hyper {
    /// Learning rate.
    pub lr: f32,
    /// L2 / decoupled weight decay coefficient (rule-dependent).
    pub weight_decay: f32,
    /// Heavy-ball momentum coefficient (SGD-momentum).
    pub momentum: f32,
    /// Adam-family first-moment decay.
    pub beta1: f32,
    /// Adam-family second-moment decay.
    pub beta2: f32,
    /// Numerical-stability epsilon.
    pub eps: f32,
    /// Adadelta decay.
    pub rho: f32,
}

impl Default for Hyper {
    fn default() -> Self {
        Self {
            lr: 1e-3,
            weight_decay: 1e-2,
            momentum: 0.9,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            rho: 0.9,
        }
    }
}

/// A per-parameter iterative update rule.
pub trait Optimizer: Send + Sync {
    /// Stable identifier used by CLI flags and bench tables.
    fn name(&self) -> &'static str;

    /// Number of state tensors per parameter (momentum buffers etc.).
    fn num_state(&self) -> usize;

    /// True if the rule needs information across all parameters (e.g.
    /// global-norm clipping). Backward-fusion cannot run such rules
    /// (paper Table 1); forward-fusion and baseline can.
    fn needs_global(&self) -> bool {
        false
    }

    /// Clip threshold consulted when [`Optimizer::needs_global`]: the
    /// executor computes `global_scale = min(1, max_norm / ‖g‖)` from it
    /// after each backward pass. Ignored for local rules.
    fn global_max_norm(&self) -> f32 {
        1.0
    }

    /// The raw elementwise kernel: one update step over equal-length
    /// value/grad slices plus `num_state()` state slices. `step` is
    /// 1-based; `global_scale` is 1.0 unless a global transform (grad
    /// clipping) was computed after backward. Implementations must also
    /// reset the gradient to zero (Fig. 2: grads are read *and reset*
    /// here). Callers guarantee `state.len() == num_state()` and that
    /// every slice has `value.len()` elements.
    fn update_slices(
        &self,
        step: u64,
        value: &mut [f32],
        grad: &mut [f32],
        state: &mut [&mut [f32]],
        hp: &Hyper,
        global_scale: f32,
    );

    /// Lane-friendly variant of [`Optimizer::update_slices`]: walks the
    /// slices in exact chunks of 8 elements plus a remainder tail so the
    /// autovectorizer can lower the chunk body without tail checks. The
    /// rules are elementwise, so chunking must not (and does not) change
    /// any per-element arithmetic — overrides are bit-identical to the
    /// scalar kernel by construction, and the default just forwards to it.
    fn update_slices_lanes(
        &self,
        step: u64,
        value: &mut [f32],
        grad: &mut [f32],
        state: &mut [&mut [f32]],
        hp: &Hyper,
        global_scale: f32,
    ) {
        self.update_slices(step, value, grad, state, hp, global_scale);
    }

    /// Apply one update step to a single parameter (scattered storage).
    /// Lazily allocates the parameter's state tensors, then runs the
    /// fused kernel through [`run_update_slices`] under the process-wide
    /// kernel mode — the historical per-`ParamData` entry point, now
    /// derived from the kernel.
    fn update(&self, step: u64, p: &mut ParamData, hp: &Hyper, global_scale: f32) {
        ensure_state(p, self.num_state());
        let ParamData { value, grad, state, .. } = p;
        let mut slots: Vec<&mut [f32]> = state.iter_mut().map(Tensor::data_mut).collect();
        run_update_slices(
            self,
            &kernel::global(),
            step,
            value.data_mut(),
            grad.data_mut(),
            &mut slots,
            hp,
            global_scale,
        );
    }

    /// Apply one update step to every member of a bucket in a single
    /// pass over its flat gradient/state buffers (see [`bucket`]).
    ///
    /// The default implementation is the *fallback contract*: it walks
    /// the members in span order, handing each member's value slice and
    /// its contiguous region of the flat buffers to
    /// [`Optimizer::update_slices`]. Because spans are tight and
    /// ordered, this is already one front-to-back sweep of the flat
    /// gradient and state arrays — an override can fuse further but must
    /// keep the math identical. The caller guarantees `bucket.state`
    /// holds `num_state()` full-length buffers.
    ///
    /// ```
    /// use optfuse::optim::bucket::{BucketViewMut, MemberMut};
    /// use optfuse::optim::{Hyper, Optimizer, Sgd};
    ///
    /// // Two parameters sharing one flat gradient buffer.
    /// let mut v1 = vec![1.0f32, 2.0];
    /// let mut v2 = vec![3.0f32];
    /// let mut grads = vec![1.0f32, 1.0, 1.0];
    /// let mut view = BucketViewMut {
    ///     grads: &mut grads,
    ///     state: Vec::new(),
    ///     members: vec![
    ///         MemberMut { value: &mut v1, offset: 0, len: 2 },
    ///         MemberMut { value: &mut v2, offset: 2, len: 1 },
    ///     ],
    /// };
    /// let hp = Hyper { lr: 0.5, weight_decay: 0.0, ..Hyper::default() };
    /// Sgd.update_bucket(1, &mut view, &hp, 1.0);
    /// // Identical math to two per-parameter Sgd updates:
    /// assert_eq!(v1, [0.5, 1.5]);
    /// assert_eq!(v2, [2.5]);
    /// assert_eq!(grads, [0.0, 0.0, 0.0], "grads are read and reset");
    /// ```
    fn update_bucket(&self, step: u64, b: &mut BucketViewMut<'_>, hp: &Hyper, global_scale: f32) {
        let cfg = kernel::global();
        for m in b.members.iter_mut() {
            let g = &mut b.grads[m.offset..m.offset + m.len];
            let mut slots: Vec<&mut [f32]> = b
                .state
                .iter_mut()
                .map(|s| &mut s[m.offset..m.offset + m.len])
                .collect();
            run_update_slices(self, &cfg, step, m.value, g, &mut slots, hp, global_scale);
        }
    }

    /// (reads, writes) of f32 elements per parameter scalar — the memory
    /// transaction footprint used by `memsim` (paper Fig. 2 analysis).
    /// Counts param/grad/state traffic of a straightforward kernel.
    fn mem_per_elem(&self) -> (u32, u32);

    /// Arithmetic ops per scalar (memsim cost model).
    fn flops_per_elem(&self) -> u32;
}

fn ensure_state(p: &mut ParamData, n: usize) {
    while p.state.len() < n {
        let shape = p.value.shape().to_vec();
        p.state.push(Tensor::zeros(&shape));
    }
}

/// Run one fused elementwise update through the selected compute kernel:
/// the scalar reference ([`Optimizer::update_slices`]), the 8-lane chunked
/// kernel ([`Optimizer::update_slices_lanes`]), or — under `simd-mt` — the
/// lane kernel over contiguous element ranges split across scoped threads.
/// The rules are elementwise and the split never crosses an element, so
/// every mode, lane width, and thread count is bit-identical.
#[allow(clippy::too_many_arguments)]
pub fn run_update_slices<O: Optimizer + ?Sized>(
    opt: &O,
    cfg: &KernelConfig,
    step: u64,
    value: &mut [f32],
    grad: &mut [f32],
    state: &mut [&mut [f32]],
    hp: &Hyper,
    global_scale: f32,
) {
    let n = value.len();
    match cfg.mode {
        KernelMode::Scalar => opt.update_slices(step, value, grad, state, hp, global_scale),
        KernelMode::Simd => opt.update_slices_lanes(step, value, grad, state, hp, global_scale),
        KernelMode::SimdMt => {
            if cfg.threads <= 1 || n < MT_MIN_ELEMS {
                opt.update_slices_lanes(step, value, grad, state, hp, global_scale);
                return;
            }
            let t = cfg.threads.min(n);
            let per = (n + t - 1) / t;
            std::thread::scope(|s| {
                let mut value = &mut *value;
                let mut grad = &mut *grad;
                let mut slabs: Vec<&mut [f32]> = state.iter_mut().map(|x| &mut x[..]).collect();
                while !value.is_empty() {
                    let take = per.min(value.len());
                    let (vh, vrest) = value.split_at_mut(take);
                    let (gh, grest) = grad.split_at_mut(take);
                    value = vrest;
                    grad = grest;
                    let mut heads: Vec<&mut [f32]> = Vec::with_capacity(slabs.len());
                    let mut rests: Vec<&mut [f32]> = Vec::with_capacity(slabs.len());
                    for sl in slabs {
                        let (h, r) = sl.split_at_mut(take);
                        heads.push(h);
                        rests.push(r);
                    }
                    slabs = rests;
                    s.spawn(move || {
                        let mut heads = heads;
                        opt.update_slices_lanes(step, vh, gh, &mut heads, hp, global_scale);
                    });
                }
            });
        }
    }
}

/// Plain SGD: θ ← θ − lr·(g + wd·θ).
pub struct Sgd;

impl Optimizer for Sgd {
    fn name(&self) -> &'static str {
        "sgd"
    }
    fn num_state(&self) -> usize {
        0
    }
    fn update_slices(
        &self,
        _step: u64,
        value: &mut [f32],
        grad: &mut [f32],
        _state: &mut [&mut [f32]],
        hp: &Hyper,
        gs: f32,
    ) {
        let wd = hp.weight_decay;
        let lr = hp.lr;
        for (v, g) in value.iter_mut().zip(grad.iter_mut()) {
            let grad = *g * gs + wd * *v;
            *v -= lr * grad;
            *g = 0.0;
        }
    }
    fn update_slices_lanes(
        &self,
        _step: u64,
        value: &mut [f32],
        grad: &mut [f32],
        _state: &mut [&mut [f32]],
        hp: &Hyper,
        gs: f32,
    ) {
        let wd = hp.weight_decay;
        let lr = hp.lr;
        let mut vi = value.chunks_exact_mut(8);
        let mut gi = grad.chunks_exact_mut(8);
        for (v8, g8) in (&mut vi).zip(&mut gi) {
            for (v, g) in v8.iter_mut().zip(g8.iter_mut()) {
                let gg = *g * gs + wd * *v;
                *v -= lr * gg;
                *g = 0.0;
            }
        }
        for (v, g) in vi.into_remainder().iter_mut().zip(gi.into_remainder().iter_mut()) {
            let gg = *g * gs + wd * *v;
            *v -= lr * gg;
            *g = 0.0;
        }
    }
    fn mem_per_elem(&self) -> (u32, u32) {
        (2, 2) // read θ,g ; write θ,g(reset)
    }
    fn flops_per_elem(&self) -> u32 {
        4
    }
}

/// SGD with (heavy-ball) momentum: m ← μ·m + g; θ ← θ − lr·m.
pub struct SgdMomentum;

impl Optimizer for SgdMomentum {
    fn name(&self) -> &'static str {
        "sgd_momentum"
    }
    fn num_state(&self) -> usize {
        1
    }
    fn update_slices(
        &self,
        _step: u64,
        value: &mut [f32],
        grad: &mut [f32],
        state: &mut [&mut [f32]],
        hp: &Hyper,
        gs: f32,
    ) {
        let (lr, mu, wd) = (hp.lr, hp.momentum, hp.weight_decay);
        for ((v, g), mm) in value.iter_mut().zip(grad.iter_mut()).zip(state[0].iter_mut()) {
            let grad = *g * gs + wd * *v;
            *mm = mu * *mm + grad;
            *v -= lr * *mm;
            *g = 0.0;
        }
    }
    fn update_slices_lanes(
        &self,
        _step: u64,
        value: &mut [f32],
        grad: &mut [f32],
        state: &mut [&mut [f32]],
        hp: &Hyper,
        gs: f32,
    ) {
        let (lr, mu, wd) = (hp.lr, hp.momentum, hp.weight_decay);
        let mut vi = value.chunks_exact_mut(8);
        let mut gi = grad.chunks_exact_mut(8);
        let mut mi = state[0].chunks_exact_mut(8);
        for ((v8, g8), m8) in (&mut vi).zip(&mut gi).zip(&mut mi) {
            for ((v, g), mm) in v8.iter_mut().zip(g8.iter_mut()).zip(m8.iter_mut()) {
                let gg = *g * gs + wd * *v;
                *mm = mu * *mm + gg;
                *v -= lr * *mm;
                *g = 0.0;
            }
        }
        for ((v, g), mm) in vi
            .into_remainder()
            .iter_mut()
            .zip(gi.into_remainder().iter_mut())
            .zip(mi.into_remainder().iter_mut())
        {
            let gg = *g * gs + wd * *v;
            *mm = mu * *mm + gg;
            *v -= lr * *mm;
            *g = 0.0;
        }
    }
    fn mem_per_elem(&self) -> (u32, u32) {
        (3, 3) // read θ,g,m ; write θ,g,m
    }
    fn flops_per_elem(&self) -> u32 {
        7
    }
}

/// Adam (Kingma & Ba 2015) with decoupled L2 applied as coupled weight
/// decay (classic Adam+wd, as used in the paper's §C.1 setup).
pub struct Adam;

impl Optimizer for Adam {
    fn name(&self) -> &'static str {
        "adam"
    }
    fn num_state(&self) -> usize {
        2
    }
    fn update_slices(
        &self,
        step: u64,
        value: &mut [f32],
        grad: &mut [f32],
        state: &mut [&mut [f32]],
        hp: &Hyper,
        gs: f32,
    ) {
        let (lr, b1, b2, eps, wd) = (hp.lr, hp.beta1, hp.beta2, hp.eps, hp.weight_decay);
        let bc1 = 1.0 - b1.powi(step as i32);
        let bc2 = 1.0 - b2.powi(step as i32);
        let (ms, vs) = state.split_at_mut(1);
        for (((v, g), mm), vv) in value
            .iter_mut()
            .zip(grad.iter_mut())
            .zip(ms[0].iter_mut())
            .zip(vs[0].iter_mut())
        {
            let grad = *g * gs + wd * *v;
            *mm = b1 * *mm + (1.0 - b1) * grad;
            *vv = b2 * *vv + (1.0 - b2) * grad * grad;
            let mhat = *mm / bc1;
            let vhat = *vv / bc2;
            *v -= lr * mhat / (vhat.sqrt() + eps);
            *g = 0.0;
        }
    }
    fn update_slices_lanes(
        &self,
        step: u64,
        value: &mut [f32],
        grad: &mut [f32],
        state: &mut [&mut [f32]],
        hp: &Hyper,
        gs: f32,
    ) {
        let (lr, b1, b2, eps, wd) = (hp.lr, hp.beta1, hp.beta2, hp.eps, hp.weight_decay);
        let bc1 = 1.0 - b1.powi(step as i32);
        let bc2 = 1.0 - b2.powi(step as i32);
        let (ms, vs) = state.split_at_mut(1);
        let mut vi = value.chunks_exact_mut(8);
        let mut gi = grad.chunks_exact_mut(8);
        let mut mi = ms[0].chunks_exact_mut(8);
        let mut si = vs[0].chunks_exact_mut(8);
        for (((v8, g8), m8), s8) in (&mut vi).zip(&mut gi).zip(&mut mi).zip(&mut si) {
            for (((v, g), mm), vv) in
                v8.iter_mut().zip(g8.iter_mut()).zip(m8.iter_mut()).zip(s8.iter_mut())
            {
                let gg = *g * gs + wd * *v;
                *mm = b1 * *mm + (1.0 - b1) * gg;
                *vv = b2 * *vv + (1.0 - b2) * gg * gg;
                let mhat = *mm / bc1;
                let vhat = *vv / bc2;
                *v -= lr * mhat / (vhat.sqrt() + eps);
                *g = 0.0;
            }
        }
        for (((v, g), mm), vv) in vi
            .into_remainder()
            .iter_mut()
            .zip(gi.into_remainder().iter_mut())
            .zip(mi.into_remainder().iter_mut())
            .zip(si.into_remainder().iter_mut())
        {
            let gg = *g * gs + wd * *v;
            *mm = b1 * *mm + (1.0 - b1) * gg;
            *vv = b2 * *vv + (1.0 - b2) * gg * gg;
            let mhat = *mm / bc1;
            let vhat = *vv / bc2;
            *v -= lr * mhat / (vhat.sqrt() + eps);
            *g = 0.0;
        }
    }
    fn mem_per_elem(&self) -> (u32, u32) {
        (4, 4) // θ,g,m,v in ; θ,g,m,v out
    }
    fn flops_per_elem(&self) -> u32 {
        13
    }
}

/// AdamW: decoupled weight decay (θ ← θ·(1 − lr·wd) before the Adam step).
pub struct AdamW;

impl Optimizer for AdamW {
    fn name(&self) -> &'static str {
        "adamw"
    }
    fn num_state(&self) -> usize {
        2
    }
    fn update_slices(
        &self,
        step: u64,
        value: &mut [f32],
        grad: &mut [f32],
        state: &mut [&mut [f32]],
        hp: &Hyper,
        gs: f32,
    ) {
        let (lr, b1, b2, eps, wd) = (hp.lr, hp.beta1, hp.beta2, hp.eps, hp.weight_decay);
        let bc1 = 1.0 - b1.powi(step as i32);
        let bc2 = 1.0 - b2.powi(step as i32);
        let (ms, vs) = state.split_at_mut(1);
        for (((v, g), mm), vv) in value
            .iter_mut()
            .zip(grad.iter_mut())
            .zip(ms[0].iter_mut())
            .zip(vs[0].iter_mut())
        {
            let grad = *g * gs;
            *v *= 1.0 - lr * wd;
            *mm = b1 * *mm + (1.0 - b1) * grad;
            *vv = b2 * *vv + (1.0 - b2) * grad * grad;
            let mhat = *mm / bc1;
            let vhat = *vv / bc2;
            *v -= lr * mhat / (vhat.sqrt() + eps);
            *g = 0.0;
        }
    }
    fn update_slices_lanes(
        &self,
        step: u64,
        value: &mut [f32],
        grad: &mut [f32],
        state: &mut [&mut [f32]],
        hp: &Hyper,
        gs: f32,
    ) {
        let (lr, b1, b2, eps, wd) = (hp.lr, hp.beta1, hp.beta2, hp.eps, hp.weight_decay);
        let bc1 = 1.0 - b1.powi(step as i32);
        let bc2 = 1.0 - b2.powi(step as i32);
        let (ms, vs) = state.split_at_mut(1);
        let mut vi = value.chunks_exact_mut(8);
        let mut gi = grad.chunks_exact_mut(8);
        let mut mi = ms[0].chunks_exact_mut(8);
        let mut si = vs[0].chunks_exact_mut(8);
        for (((v8, g8), m8), s8) in (&mut vi).zip(&mut gi).zip(&mut mi).zip(&mut si) {
            for (((v, g), mm), vv) in
                v8.iter_mut().zip(g8.iter_mut()).zip(m8.iter_mut()).zip(s8.iter_mut())
            {
                let gg = *g * gs;
                *v *= 1.0 - lr * wd;
                *mm = b1 * *mm + (1.0 - b1) * gg;
                *vv = b2 * *vv + (1.0 - b2) * gg * gg;
                let mhat = *mm / bc1;
                let vhat = *vv / bc2;
                *v -= lr * mhat / (vhat.sqrt() + eps);
                *g = 0.0;
            }
        }
        for (((v, g), mm), vv) in vi
            .into_remainder()
            .iter_mut()
            .zip(gi.into_remainder().iter_mut())
            .zip(mi.into_remainder().iter_mut())
            .zip(si.into_remainder().iter_mut())
        {
            let gg = *g * gs;
            *v *= 1.0 - lr * wd;
            *mm = b1 * *mm + (1.0 - b1) * gg;
            *vv = b2 * *vv + (1.0 - b2) * gg * gg;
            let mhat = *mm / bc1;
            let vhat = *vv / bc2;
            *v -= lr * mhat / (vhat.sqrt() + eps);
            *g = 0.0;
        }
    }
    fn mem_per_elem(&self) -> (u32, u32) {
        (4, 4)
    }
    fn flops_per_elem(&self) -> u32 {
        14
    }
}

/// Adagrad (Duchi et al. 2011): h ← h + g²; θ ← θ − lr·g/(√h + eps).
pub struct Adagrad;

impl Optimizer for Adagrad {
    fn name(&self) -> &'static str {
        "adagrad"
    }
    fn num_state(&self) -> usize {
        1
    }
    fn update_slices(
        &self,
        _step: u64,
        value: &mut [f32],
        grad: &mut [f32],
        state: &mut [&mut [f32]],
        hp: &Hyper,
        gs: f32,
    ) {
        let (lr, eps, wd) = (hp.lr, hp.eps, hp.weight_decay);
        for ((v, g), hh) in value.iter_mut().zip(grad.iter_mut()).zip(state[0].iter_mut()) {
            let grad = *g * gs + wd * *v;
            *hh += grad * grad;
            *v -= lr * grad / (hh.sqrt() + eps);
            *g = 0.0;
        }
    }
    fn mem_per_elem(&self) -> (u32, u32) {
        (3, 3)
    }
    fn flops_per_elem(&self) -> u32 {
        8
    }
}

/// Adadelta (Zeiler 2012): two running averages, no explicit lr.
pub struct Adadelta;

impl Optimizer for Adadelta {
    fn name(&self) -> &'static str {
        "adadelta"
    }
    fn num_state(&self) -> usize {
        2
    }
    fn update_slices(
        &self,
        _step: u64,
        value: &mut [f32],
        grad: &mut [f32],
        state: &mut [&mut [f32]],
        hp: &Hyper,
        gs: f32,
    ) {
        let (rho, eps, wd) = (hp.rho, hp.eps, hp.weight_decay);
        let (eg, ex) = state.split_at_mut(1);
        for (((v, g), egg), exx) in value
            .iter_mut()
            .zip(grad.iter_mut())
            .zip(eg[0].iter_mut())
            .zip(ex[0].iter_mut())
        {
            let grad = *g * gs + wd * *v;
            *egg = rho * *egg + (1.0 - rho) * grad * grad;
            let dx = -((*exx + eps).sqrt() / (*egg + eps).sqrt()) * grad;
            *exx = rho * *exx + (1.0 - rho) * dx * dx;
            *v += dx;
            *g = 0.0;
        }
    }
    fn mem_per_elem(&self) -> (u32, u32) {
        (4, 4)
    }
    fn flops_per_elem(&self) -> u32 {
        14
    }
}

/// RMSprop: v ← ρ·v + (1-ρ)·g²; θ ← θ − lr·g/(√v + eps).
pub struct RmsProp;

impl Optimizer for RmsProp {
    fn name(&self) -> &'static str {
        "rmsprop"
    }
    fn num_state(&self) -> usize {
        1
    }
    fn update_slices(
        &self,
        _step: u64,
        value: &mut [f32],
        grad: &mut [f32],
        state: &mut [&mut [f32]],
        hp: &Hyper,
        gs: f32,
    ) {
        let (lr, rho, eps, wd) = (hp.lr, hp.rho, hp.eps, hp.weight_decay);
        for ((v, g), vv) in value.iter_mut().zip(grad.iter_mut()).zip(state[0].iter_mut()) {
            let grad = *g * gs + wd * *v;
            *vv = rho * *vv + (1.0 - rho) * grad * grad;
            *v -= lr * grad / (vv.sqrt() + eps);
            *g = 0.0;
        }
    }
    fn mem_per_elem(&self) -> (u32, u32) {
        (3, 3)
    }
    fn flops_per_elem(&self) -> u32 {
        9
    }
}

/// Wraps any optimizer with global-gradient-norm clipping — an update rule
/// that **needs global information** (paper Table 1 / §B.1: supported by
/// forward-fusion, rejected by backward-fusion).
pub struct GlobalNormClip<O> {
    /// The wrapped local update rule.
    pub inner: O,
    /// Clip threshold on the global gradient L2 norm.
    pub max_norm: f32,
}

impl<O: Optimizer> Optimizer for GlobalNormClip<O> {
    fn name(&self) -> &'static str {
        "global_norm_clip"
    }
    fn num_state(&self) -> usize {
        self.inner.num_state()
    }
    fn needs_global(&self) -> bool {
        true
    }
    fn global_max_norm(&self) -> f32 {
        self.max_norm
    }
    /// `global_scale` must be the precomputed clip factor
    /// min(1, max_norm / ||g||_global); the per-parameter work is local.
    fn update_slices(
        &self,
        step: u64,
        value: &mut [f32],
        grad: &mut [f32],
        state: &mut [&mut [f32]],
        hp: &Hyper,
        global_scale: f32,
    ) {
        self.inner.update_slices(step, value, grad, state, hp, global_scale);
    }
    fn update_slices_lanes(
        &self,
        step: u64,
        value: &mut [f32],
        grad: &mut [f32],
        state: &mut [&mut [f32]],
        hp: &Hyper,
        global_scale: f32,
    ) {
        self.inner.update_slices_lanes(step, value, grad, state, hp, global_scale);
    }
    fn mem_per_elem(&self) -> (u32, u32) {
        let (r, w) = self.inner.mem_per_elem();
        (r + 1, w) // extra grad read for the norm pass
    }
    fn flops_per_elem(&self) -> u32 {
        self.inner.flops_per_elem() + 2
    }
}

/// Construct an optimizer by name (CLI / bench sweeps).
pub fn by_name(name: &str) -> Option<Box<dyn Optimizer>> {
    Some(match name {
        "sgd" => Box::new(Sgd),
        "sgd_momentum" | "momentum" => Box::new(SgdMomentum),
        "adam" => Box::new(Adam),
        "adamw" => Box::new(AdamW),
        "adagrad" => Box::new(Adagrad),
        "adadelta" => Box::new(Adadelta),
        "rmsprop" => Box::new(RmsProp),
        "adam_clip" => Box::new(GlobalNormClip { inner: Adam, max_norm: 1.0 }),
        _ => return None,
    })
}

/// All local (BF-compatible) optimizer names, for sweeps (paper Fig. 7).
pub const LOCAL_OPTIMIZERS: [&str; 7] = [
    "sgd",
    "sgd_momentum",
    "adam",
    "adamw",
    "adagrad",
    "adadelta",
    "rmsprop",
];

#[cfg(test)]
mod tests {
    use super::*;

    fn mk_param(vals: &[f32], grads: &[f32]) -> ParamData {
        ParamData {
            name: "p".into(),
            value: Tensor::from_vec(&[vals.len()], vals.to_vec()),
            grad: Tensor::from_vec(&[grads.len()], grads.to_vec()),
            state: Vec::new(),
        }
    }

    fn hp_nodecay() -> Hyper {
        Hyper { weight_decay: 0.0, ..Hyper::default() }
    }

    #[test]
    fn sgd_step_and_grad_reset() {
        let mut p = mk_param(&[1.0, 2.0], &[0.5, -0.5]);
        let hp = Hyper { lr: 0.1, weight_decay: 0.0, ..Hyper::default() };
        Sgd.update(1, &mut p, &hp, 1.0);
        assert_eq!(p.value.data(), &[0.95, 2.05]);
        assert_eq!(p.grad.data(), &[0.0, 0.0], "grad must be reset");
    }

    #[test]
    fn sgd_weight_decay() {
        let mut p = mk_param(&[1.0], &[0.0]);
        let hp = Hyper { lr: 0.1, weight_decay: 0.5, ..Hyper::default() };
        Sgd.update(1, &mut p, &hp, 1.0);
        assert!((p.value.data()[0] - 0.95).abs() < 1e-6);
    }

    #[test]
    fn momentum_accumulates() {
        let mut p = mk_param(&[0.0], &[1.0]);
        let hp = Hyper { lr: 1.0, momentum: 0.5, weight_decay: 0.0, ..Hyper::default() };
        SgdMomentum.update(1, &mut p, &hp, 1.0);
        assert_eq!(p.value.data(), &[-1.0]);
        p.grad.data_mut()[0] = 1.0;
        SgdMomentum.update(2, &mut p, &hp, 1.0);
        // m = 0.5*1 + 1 = 1.5 -> θ = -1 - 1.5 = -2.5
        assert_eq!(p.value.data(), &[-2.5]);
    }

    #[test]
    fn adam_first_step_is_lr_sized() {
        // bias correction makes |Δθ| ≈ lr on step 1 regardless of grad scale
        let mut p = mk_param(&[0.0], &[1e-3]);
        let hp = hp_nodecay();
        Adam.update(1, &mut p, &hp, 1.0);
        assert!((p.value.data()[0].abs() - hp.lr).abs() < 1e-4, "{}", p.value.data()[0]);
    }

    #[test]
    fn adamw_decay_decoupled() {
        let mut p = mk_param(&[1.0], &[0.0]);
        let hp = Hyper { lr: 0.1, weight_decay: 0.5, ..Hyper::default() };
        AdamW.update(1, &mut p, &hp, 1.0);
        // grad=0 so only decay applies: 1 * (1 - 0.1*0.5) = 0.95
        assert!((p.value.data()[0] - 0.95).abs() < 1e-6);
    }

    #[test]
    fn adagrad_lr_shrinks() {
        let mut p = mk_param(&[0.0], &[1.0]);
        let hp = Hyper { lr: 1.0, weight_decay: 0.0, eps: 0.0, ..Hyper::default() };
        Adagrad.update(1, &mut p, &hp, 1.0);
        let d1 = p.value.data()[0].abs(); // 1/sqrt(1) = 1
        p.grad.data_mut()[0] = 1.0;
        let before = p.value.data()[0];
        Adagrad.update(2, &mut p, &hp, 1.0);
        let d2 = (p.value.data()[0] - before).abs(); // 1/sqrt(2)
        assert!(d2 < d1);
        assert!((d2 - 1.0 / 2.0f32.sqrt()).abs() < 1e-5);
    }

    #[test]
    fn adadelta_moves_against_gradient() {
        let mut p = mk_param(&[1.0], &[1.0]);
        Adadelta.update(1, &mut p, &hp_nodecay(), 1.0);
        assert!(p.value.data()[0] < 1.0);
    }

    #[test]
    fn rmsprop_step() {
        let mut p = mk_param(&[0.0], &[2.0]);
        let hp = Hyper { lr: 0.1, weight_decay: 0.0, rho: 0.0, eps: 0.0, ..Hyper::default() };
        // v = g², step = lr·g/|g| = lr·sign(g)
        RmsProp.update(1, &mut p, &hp, 1.0);
        assert!((p.value.data()[0] + 0.1).abs() < 1e-5);
    }

    #[test]
    fn global_scale_applied() {
        let mut p = mk_param(&[0.0], &[10.0]);
        let hp = Hyper { lr: 1.0, weight_decay: 0.0, ..Hyper::default() };
        let clip = GlobalNormClip { inner: Sgd, max_norm: 1.0 };
        assert!(clip.needs_global());
        clip.update(1, &mut p, &hp, 0.1); // scale 0.1 => effective grad 1.0
        assert!((p.value.data()[0] + 1.0).abs() < 1e-6);
    }

    #[test]
    fn by_name_roundtrip() {
        for n in LOCAL_OPTIMIZERS {
            let o = by_name(n).unwrap();
            assert!(!o.needs_global(), "{n}");
        }
        assert!(by_name("adam_clip").unwrap().needs_global());
        assert!(by_name("bogus").is_none());
    }

    #[test]
    fn update_bucket_default_matches_per_param() {
        use bucket::{BucketViewMut, MemberMut};
        let hp = hp_nodecay();
        // per-param reference (two steps so Adam state matters)
        let mut p1 = mk_param(&[1.0, -2.0], &[0.3, 0.4]);
        let mut p2 = mk_param(&[0.5, 0.25, -1.0], &[0.1, -0.2, 0.3]);
        // bucketed twin over one flat grad + flat state pair
        let mut v1 = vec![1.0f32, -2.0];
        let mut v2 = vec![0.5f32, 0.25, -1.0];
        let mut grads = vec![0.3f32, 0.4, 0.1, -0.2, 0.3];
        let mut m = vec![0.0f32; 5];
        let mut s = vec![0.0f32; 5];
        for step in 1..=2u64 {
            Adam.update(step, &mut p1, &hp, 1.0);
            Adam.update(step, &mut p2, &hp, 1.0);
            {
                let (ms, ss) = (&mut m[..], &mut s[..]);
                let mut view = BucketViewMut {
                    grads: &mut grads,
                    state: vec![ms, ss],
                    members: vec![
                        MemberMut { value: &mut v1, offset: 0, len: 2 },
                        MemberMut { value: &mut v2, offset: 2, len: 3 },
                    ],
                };
                Adam.update_bucket(step, &mut view, &hp, 1.0);
            }
            assert_eq!(v1.as_slice(), p1.value.data(), "step {step}: p1 values");
            assert_eq!(v2.as_slice(), p2.value.data(), "step {step}: p2 values");
            assert_eq!(&m[..2], p1.state[0].data(), "step {step}: p1 m-state");
            assert_eq!(&m[2..], p2.state[0].data(), "step {step}: p2 m-state");
            assert!(grads.iter().all(|g| *g == 0.0), "grads reset");
            // refill identical grads for the next step
            for (i, g) in [0.05f32, -0.1, 0.2, 0.0, -0.3].iter().enumerate() {
                grads[i] = *g;
            }
            p1.grad = Tensor::from_vec(&[2], grads[..2].to_vec());
            p2.grad = Tensor::from_vec(&[3], grads[2..].to_vec());
        }
    }

    #[test]
    fn lanes_kernel_matches_scalar() {
        // The 8-chunked kernels must be bit-identical to the plain loops,
        // including the remainder tail (n = 29) and nontrivial state.
        for name in LOCAL_OPTIMIZERS {
            let opt = by_name(name).unwrap();
            let hp = Hyper::default();
            let n = 29;
            let value: Vec<f32> = (0..n).map(|i| (i as f32 * 0.13).sin()).collect();
            let grad: Vec<f32> = (0..n).map(|i| (i as f32 * 0.29).cos()).collect();
            let mut v0 = value.clone();
            let mut g0 = grad.clone();
            let mut s0 = vec![vec![0.1f32; n]; opt.num_state()];
            {
                let mut slots: Vec<&mut [f32]> = s0.iter_mut().map(|s| &mut s[..]).collect();
                opt.update_slices(2, &mut v0, &mut g0, &mut slots, &hp, 1.0);
            }
            let mut v1 = value.clone();
            let mut g1 = grad.clone();
            let mut s1 = vec![vec![0.1f32; n]; opt.num_state()];
            {
                let mut slots: Vec<&mut [f32]> = s1.iter_mut().map(|s| &mut s[..]).collect();
                opt.update_slices_lanes(2, &mut v1, &mut g1, &mut slots, &hp, 1.0);
            }
            assert_eq!(v0, v1, "{name} values");
            assert_eq!(g0, g1, "{name} grads");
            assert_eq!(s0, s1, "{name} state");
        }
    }

    #[test]
    fn state_allocated_lazily() {
        let mut p = mk_param(&[1.0, 2.0, 3.0], &[0.1, 0.1, 0.1]);
        assert!(p.state.is_empty());
        Adam.update(1, &mut p, &hp_nodecay(), 1.0);
        assert_eq!(p.state.len(), 2);
        assert_eq!(p.state[0].shape(), &[3]);
    }
}
