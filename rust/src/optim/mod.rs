//! Iterative optimizers (paper §A, Alg. 1). Each optimizer is a pure
//! per-parameter update rule: the *schedule* that decides **when** each
//! update runs lives in `exec/` — that separation is exactly what lets the
//! same optimizer run under baseline, forward-fusion, or backward-fusion
//! without changing its math (the paper's "plug-in" property).
//!
//! Per the paper's Fig. 2 memory model, the update also *resets the
//! gradient* — grads are "read and reset by the optimizer".

pub mod sched;

use crate::graph::ParamData;
use crate::tensor::Tensor;

/// Hyper-parameters shared across optimizers.
#[derive(Debug, Clone)]
pub struct Hyper {
    pub lr: f32,
    pub weight_decay: f32,
    pub momentum: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    /// Adadelta decay.
    pub rho: f32,
}

impl Default for Hyper {
    fn default() -> Self {
        Self {
            lr: 1e-3,
            weight_decay: 1e-2,
            momentum: 0.9,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            rho: 0.9,
        }
    }
}

/// A per-parameter iterative update rule.
pub trait Optimizer: Send + Sync {
    fn name(&self) -> &'static str;

    /// Number of state tensors per parameter (momentum buffers etc.).
    fn num_state(&self) -> usize;

    /// True if the rule needs information across all parameters (e.g.
    /// global-norm clipping). Backward-fusion cannot run such rules
    /// (paper Table 1); forward-fusion and baseline can.
    fn needs_global(&self) -> bool {
        false
    }

    /// Apply one update step to a single parameter. `step` is 1-based.
    /// `global_scale` is 1.0 unless a global transform (grad clipping)
    /// was computed after backward. Implementations must also reset the
    /// gradient to zero (Fig. 2: grads are read *and reset* here).
    fn update(&self, step: u64, p: &mut ParamData, hp: &Hyper, global_scale: f32);

    /// (reads, writes) of f32 elements per parameter scalar — the memory
    /// transaction footprint used by `memsim` (paper Fig. 2 analysis).
    /// Counts param/grad/state traffic of a straightforward kernel.
    fn mem_per_elem(&self) -> (u32, u32);

    /// Arithmetic ops per scalar (memsim cost model).
    fn flops_per_elem(&self) -> u32;
}

fn ensure_state(p: &mut ParamData, n: usize) {
    while p.state.len() < n {
        let shape = p.value.shape().to_vec();
        p.state.push(Tensor::zeros(&shape));
    }
}

/// Plain SGD: θ ← θ − lr·(g + wd·θ).
pub struct Sgd;

impl Optimizer for Sgd {
    fn name(&self) -> &'static str {
        "sgd"
    }
    fn num_state(&self) -> usize {
        0
    }
    fn update(&self, _step: u64, p: &mut ParamData, hp: &Hyper, gs: f32) {
        let wd = hp.weight_decay;
        let lr = hp.lr;
        for (v, g) in p.value.data_mut().iter_mut().zip(p.grad.data_mut().iter_mut()) {
            let grad = *g * gs + wd * *v;
            *v -= lr * grad;
            *g = 0.0;
        }
    }
    fn mem_per_elem(&self) -> (u32, u32) {
        (2, 2) // read θ,g ; write θ,g(reset)
    }
    fn flops_per_elem(&self) -> u32 {
        4
    }
}

/// SGD with (heavy-ball) momentum: m ← μ·m + g; θ ← θ − lr·m.
pub struct SgdMomentum;

impl Optimizer for SgdMomentum {
    fn name(&self) -> &'static str {
        "sgd_momentum"
    }
    fn num_state(&self) -> usize {
        1
    }
    fn update(&self, _step: u64, p: &mut ParamData, hp: &Hyper, gs: f32) {
        ensure_state(p, 1);
        let (lr, mu, wd) = (hp.lr, hp.momentum, hp.weight_decay);
        let ParamData { value, grad, state, .. } = p;
        let m = &mut state[0];
        for ((v, g), mm) in value
            .data_mut()
            .iter_mut()
            .zip(grad.data_mut().iter_mut())
            .zip(m.data_mut().iter_mut())
        {
            let grad = *g * gs + wd * *v;
            *mm = mu * *mm + grad;
            *v -= lr * *mm;
            *g = 0.0;
        }
    }
    fn mem_per_elem(&self) -> (u32, u32) {
        (3, 3) // read θ,g,m ; write θ,g,m
    }
    fn flops_per_elem(&self) -> u32 {
        7
    }
}

/// Adam (Kingma & Ba 2015) with decoupled L2 applied as coupled weight
/// decay (classic Adam+wd, as used in the paper's §C.1 setup).
pub struct Adam;

impl Optimizer for Adam {
    fn name(&self) -> &'static str {
        "adam"
    }
    fn num_state(&self) -> usize {
        2
    }
    fn update(&self, step: u64, p: &mut ParamData, hp: &Hyper, gs: f32) {
        ensure_state(p, 2);
        let (lr, b1, b2, eps, wd) = (hp.lr, hp.beta1, hp.beta2, hp.eps, hp.weight_decay);
        let bc1 = 1.0 - b1.powi(step as i32);
        let bc2 = 1.0 - b2.powi(step as i32);
        let ParamData { value, grad, state, .. } = p;
        let (ms, vs) = state.split_at_mut(1);
        let m = &mut ms[0];
        let v2 = &mut vs[0];
        for (((v, g), mm), vv) in value
            .data_mut()
            .iter_mut()
            .zip(grad.data_mut().iter_mut())
            .zip(m.data_mut().iter_mut())
            .zip(v2.data_mut().iter_mut())
        {
            let grad = *g * gs + wd * *v;
            *mm = b1 * *mm + (1.0 - b1) * grad;
            *vv = b2 * *vv + (1.0 - b2) * grad * grad;
            let mhat = *mm / bc1;
            let vhat = *vv / bc2;
            *v -= lr * mhat / (vhat.sqrt() + eps);
            *g = 0.0;
        }
    }
    fn mem_per_elem(&self) -> (u32, u32) {
        (4, 4) // θ,g,m,v in ; θ,g,m,v out
    }
    fn flops_per_elem(&self) -> u32 {
        13
    }
}

/// AdamW: decoupled weight decay (θ ← θ·(1 − lr·wd) before the Adam step).
pub struct AdamW;

impl Optimizer for AdamW {
    fn name(&self) -> &'static str {
        "adamw"
    }
    fn num_state(&self) -> usize {
        2
    }
    fn update(&self, step: u64, p: &mut ParamData, hp: &Hyper, gs: f32) {
        ensure_state(p, 2);
        let (lr, b1, b2, eps, wd) = (hp.lr, hp.beta1, hp.beta2, hp.eps, hp.weight_decay);
        let bc1 = 1.0 - b1.powi(step as i32);
        let bc2 = 1.0 - b2.powi(step as i32);
        let ParamData { value, grad, state, .. } = p;
        let (ms, vs) = state.split_at_mut(1);
        let m = &mut ms[0];
        let v2 = &mut vs[0];
        for (((v, g), mm), vv) in value
            .data_mut()
            .iter_mut()
            .zip(grad.data_mut().iter_mut())
            .zip(m.data_mut().iter_mut())
            .zip(v2.data_mut().iter_mut())
        {
            let grad = *g * gs;
            *v *= 1.0 - lr * wd;
            *mm = b1 * *mm + (1.0 - b1) * grad;
            *vv = b2 * *vv + (1.0 - b2) * grad * grad;
            let mhat = *mm / bc1;
            let vhat = *vv / bc2;
            *v -= lr * mhat / (vhat.sqrt() + eps);
            *g = 0.0;
        }
    }
    fn mem_per_elem(&self) -> (u32, u32) {
        (4, 4)
    }
    fn flops_per_elem(&self) -> u32 {
        14
    }
}

/// Adagrad (Duchi et al. 2011): h ← h + g²; θ ← θ − lr·g/(√h + eps).
pub struct Adagrad;

impl Optimizer for Adagrad {
    fn name(&self) -> &'static str {
        "adagrad"
    }
    fn num_state(&self) -> usize {
        1
    }
    fn update(&self, _step: u64, p: &mut ParamData, hp: &Hyper, gs: f32) {
        ensure_state(p, 1);
        let (lr, eps, wd) = (hp.lr, hp.eps, hp.weight_decay);
        let ParamData { value, grad, state, .. } = p;
        let h = &mut state[0];
        for ((v, g), hh) in value
            .data_mut()
            .iter_mut()
            .zip(grad.data_mut().iter_mut())
            .zip(h.data_mut().iter_mut())
        {
            let grad = *g * gs + wd * *v;
            *hh += grad * grad;
            *v -= lr * grad / (hh.sqrt() + eps);
            *g = 0.0;
        }
    }
    fn mem_per_elem(&self) -> (u32, u32) {
        (3, 3)
    }
    fn flops_per_elem(&self) -> u32 {
        8
    }
}

/// Adadelta (Zeiler 2012): two running averages, no explicit lr.
pub struct Adadelta;

impl Optimizer for Adadelta {
    fn name(&self) -> &'static str {
        "adadelta"
    }
    fn num_state(&self) -> usize {
        2
    }
    fn update(&self, _step: u64, p: &mut ParamData, hp: &Hyper, gs: f32) {
        ensure_state(p, 2);
        let (rho, eps, wd) = (hp.rho, hp.eps, hp.weight_decay);
        let ParamData { value, grad, state, .. } = p;
        let (eg, ex) = state.split_at_mut(1);
        let eg2 = &mut eg[0];
        let ex2 = &mut ex[0];
        for (((v, g), egg), exx) in value
            .data_mut()
            .iter_mut()
            .zip(grad.data_mut().iter_mut())
            .zip(eg2.data_mut().iter_mut())
            .zip(ex2.data_mut().iter_mut())
        {
            let grad = *g * gs + wd * *v;
            *egg = rho * *egg + (1.0 - rho) * grad * grad;
            let dx = -((*exx + eps).sqrt() / (*egg + eps).sqrt()) * grad;
            *exx = rho * *exx + (1.0 - rho) * dx * dx;
            *v += dx;
            *g = 0.0;
        }
    }
    fn mem_per_elem(&self) -> (u32, u32) {
        (4, 4)
    }
    fn flops_per_elem(&self) -> u32 {
        14
    }
}

/// RMSprop: v ← ρ·v + (1-ρ)·g²; θ ← θ − lr·g/(√v + eps).
pub struct RmsProp;

impl Optimizer for RmsProp {
    fn name(&self) -> &'static str {
        "rmsprop"
    }
    fn num_state(&self) -> usize {
        1
    }
    fn update(&self, _step: u64, p: &mut ParamData, hp: &Hyper, gs: f32) {
        ensure_state(p, 1);
        let (lr, rho, eps, wd) = (hp.lr, hp.rho, hp.eps, hp.weight_decay);
        let ParamData { value, grad, state, .. } = p;
        let v2 = &mut state[0];
        for ((v, g), vv) in value
            .data_mut()
            .iter_mut()
            .zip(grad.data_mut().iter_mut())
            .zip(v2.data_mut().iter_mut())
        {
            let grad = *g * gs + wd * *v;
            *vv = rho * *vv + (1.0 - rho) * grad * grad;
            *v -= lr * grad / (vv.sqrt() + eps);
            *g = 0.0;
        }
    }
    fn mem_per_elem(&self) -> (u32, u32) {
        (3, 3)
    }
    fn flops_per_elem(&self) -> u32 {
        9
    }
}

/// Wraps any optimizer with global-gradient-norm clipping — an update rule
/// that **needs global information** (paper Table 1 / §B.1: supported by
/// forward-fusion, rejected by backward-fusion).
pub struct GlobalNormClip<O> {
    pub inner: O,
    pub max_norm: f32,
}

impl<O: Optimizer> Optimizer for GlobalNormClip<O> {
    fn name(&self) -> &'static str {
        "global_norm_clip"
    }
    fn num_state(&self) -> usize {
        self.inner.num_state()
    }
    fn needs_global(&self) -> bool {
        true
    }
    /// `global_scale` must be the precomputed clip factor
    /// min(1, max_norm / ||g||_global); the per-parameter work is local.
    fn update(&self, step: u64, p: &mut ParamData, hp: &Hyper, global_scale: f32) {
        self.inner.update(step, p, hp, global_scale);
    }
    fn mem_per_elem(&self) -> (u32, u32) {
        let (r, w) = self.inner.mem_per_elem();
        (r + 1, w) // extra grad read for the norm pass
    }
    fn flops_per_elem(&self) -> u32 {
        self.inner.flops_per_elem() + 2
    }
}

/// Construct an optimizer by name (CLI / bench sweeps).
pub fn by_name(name: &str) -> Option<Box<dyn Optimizer>> {
    Some(match name {
        "sgd" => Box::new(Sgd),
        "sgd_momentum" | "momentum" => Box::new(SgdMomentum),
        "adam" => Box::new(Adam),
        "adamw" => Box::new(AdamW),
        "adagrad" => Box::new(Adagrad),
        "adadelta" => Box::new(Adadelta),
        "rmsprop" => Box::new(RmsProp),
        "adam_clip" => Box::new(GlobalNormClip { inner: Adam, max_norm: 1.0 }),
        _ => return None,
    })
}

/// All local (BF-compatible) optimizer names, for sweeps (paper Fig. 7).
pub const LOCAL_OPTIMIZERS: [&str; 7] = [
    "sgd",
    "sgd_momentum",
    "adam",
    "adamw",
    "adagrad",
    "adadelta",
    "rmsprop",
];

#[cfg(test)]
mod tests {
    use super::*;

    fn mk_param(vals: &[f32], grads: &[f32]) -> ParamData {
        ParamData {
            name: "p".into(),
            value: Tensor::from_vec(&[vals.len()], vals.to_vec()),
            grad: Tensor::from_vec(&[grads.len()], grads.to_vec()),
            state: Vec::new(),
        }
    }

    fn hp_nodecay() -> Hyper {
        Hyper { weight_decay: 0.0, ..Hyper::default() }
    }

    #[test]
    fn sgd_step_and_grad_reset() {
        let mut p = mk_param(&[1.0, 2.0], &[0.5, -0.5]);
        let hp = Hyper { lr: 0.1, weight_decay: 0.0, ..Hyper::default() };
        Sgd.update(1, &mut p, &hp, 1.0);
        assert_eq!(p.value.data(), &[0.95, 2.05]);
        assert_eq!(p.grad.data(), &[0.0, 0.0], "grad must be reset");
    }

    #[test]
    fn sgd_weight_decay() {
        let mut p = mk_param(&[1.0], &[0.0]);
        let hp = Hyper { lr: 0.1, weight_decay: 0.5, ..Hyper::default() };
        Sgd.update(1, &mut p, &hp, 1.0);
        assert!((p.value.data()[0] - 0.95).abs() < 1e-6);
    }

    #[test]
    fn momentum_accumulates() {
        let mut p = mk_param(&[0.0], &[1.0]);
        let hp = Hyper { lr: 1.0, momentum: 0.5, weight_decay: 0.0, ..Hyper::default() };
        SgdMomentum.update(1, &mut p, &hp, 1.0);
        assert_eq!(p.value.data(), &[-1.0]);
        p.grad.data_mut()[0] = 1.0;
        SgdMomentum.update(2, &mut p, &hp, 1.0);
        // m = 0.5*1 + 1 = 1.5 -> θ = -1 - 1.5 = -2.5
        assert_eq!(p.value.data(), &[-2.5]);
    }

    #[test]
    fn adam_first_step_is_lr_sized() {
        // bias correction makes |Δθ| ≈ lr on step 1 regardless of grad scale
        let mut p = mk_param(&[0.0], &[1e-3]);
        let hp = hp_nodecay();
        Adam.update(1, &mut p, &hp, 1.0);
        assert!((p.value.data()[0].abs() - hp.lr).abs() < 1e-4, "{}", p.value.data()[0]);
    }

    #[test]
    fn adamw_decay_decoupled() {
        let mut p = mk_param(&[1.0], &[0.0]);
        let hp = Hyper { lr: 0.1, weight_decay: 0.5, ..Hyper::default() };
        AdamW.update(1, &mut p, &hp, 1.0);
        // grad=0 so only decay applies: 1 * (1 - 0.1*0.5) = 0.95
        assert!((p.value.data()[0] - 0.95).abs() < 1e-6);
    }

    #[test]
    fn adagrad_lr_shrinks() {
        let mut p = mk_param(&[0.0], &[1.0]);
        let hp = Hyper { lr: 1.0, weight_decay: 0.0, eps: 0.0, ..Hyper::default() };
        Adagrad.update(1, &mut p, &hp, 1.0);
        let d1 = p.value.data()[0].abs(); // 1/sqrt(1) = 1
        p.grad.data_mut()[0] = 1.0;
        let before = p.value.data()[0];
        Adagrad.update(2, &mut p, &hp, 1.0);
        let d2 = (p.value.data()[0] - before).abs(); // 1/sqrt(2)
        assert!(d2 < d1);
        assert!((d2 - 1.0 / 2.0f32.sqrt()).abs() < 1e-5);
    }

    #[test]
    fn adadelta_moves_against_gradient() {
        let mut p = mk_param(&[1.0], &[1.0]);
        Adadelta.update(1, &mut p, &hp_nodecay(), 1.0);
        assert!(p.value.data()[0] < 1.0);
    }

    #[test]
    fn rmsprop_step() {
        let mut p = mk_param(&[0.0], &[2.0]);
        let hp = Hyper { lr: 0.1, weight_decay: 0.0, rho: 0.0, eps: 0.0, ..Hyper::default() };
        // v = g², step = lr·g/|g| = lr·sign(g)
        RmsProp.update(1, &mut p, &hp, 1.0);
        assert!((p.value.data()[0] + 0.1).abs() < 1e-5);
    }

    #[test]
    fn global_scale_applied() {
        let mut p = mk_param(&[0.0], &[10.0]);
        let hp = Hyper { lr: 1.0, weight_decay: 0.0, ..Hyper::default() };
        let clip = GlobalNormClip { inner: Sgd, max_norm: 1.0 };
        assert!(clip.needs_global());
        clip.update(1, &mut p, &hp, 0.1); // scale 0.1 => effective grad 1.0
        assert!((p.value.data()[0] + 1.0).abs() < 1e-6);
    }

    #[test]
    fn by_name_roundtrip() {
        for n in LOCAL_OPTIMIZERS {
            let o = by_name(n).unwrap();
            assert!(!o.needs_global(), "{n}");
        }
        assert!(by_name("adam_clip").unwrap().needs_global());
        assert!(by_name("bogus").is_none());
    }

    #[test]
    fn state_allocated_lazily() {
        let mut p = mk_param(&[1.0, 2.0, 3.0], &[0.1, 0.1, 0.1]);
        assert!(p.state.is_empty());
        Adam.update(1, &mut p, &hp_nodecay(), 1.0);
        assert_eq!(p.state.len(), 2);
        assert_eq!(p.state[0].shape(), &[3]);
    }
}
