//! Bucketed flat-parameter storage: size-capped groups of parameters
//! whose **gradients and optimizer state** live in one contiguous
//! backing [`Tensor`] per bucket (built on [`crate::tensor::flat`]).
//!
//! The per-parameter `ParamData` allocations of the scattered layout are
//! exactly the storage pattern that Bagua's `FusedOptimizer` and IPEX
//! optimizer fusion eliminate: when every parameter owns its own heap
//! blocks, an optimizer pass (or a DDP all-reduce) hops allocations and
//! pays per-parameter dispatch, locking, and cache-miss overhead. A
//! bucket replaces that with one flat gradient buffer and one flat
//! buffer per optimizer-state slot, walked front to back in a single
//! fused pass ([`crate::optim::Optimizer::update_bucket`]).
//!
//! Parameter *values* intentionally stay per-parameter: the graph ops
//! borrow `&Tensor` views of each value during forward/backward, so the
//! value allocation is owned by the compute path, not the update path.
//! The update and communication paths — which this module serves — own
//! grads and state exclusively, and those are fully flattened. The
//! schedule machinery treats a bucket as one schedulable unit: under
//! backward-fusion a bucket fires as soon as the gradients of *all* its
//! members are complete (per-bucket refcount, preserving the §B.2 race
//! guard), and under forward-fusion right before the first member is
//! used by the next forward pass.
//!
//! Lock order: a bucket's lock is always taken **before** any member
//! parameter lock, and member locks are taken in member order; the
//! forward/backward path never holds a parameter lock while acquiring a
//! bucket lock. That ordering makes concurrent pool updates deadlock-free.

use crate::exec::kernel;
use crate::graph::{ParamId, ParamRef};
use crate::optim::{run_update_slices, Hyper, Optimizer};
use crate::tensor::dtype::Dtype;
use crate::tensor::flat::FlatLayout;
use crate::tensor::Tensor;
use std::sync::{Arc, RwLock};

/// One parameter's membership in a bucket.
pub struct Member {
    /// The parameter's id in the owning `ParamStore`.
    pub pid: ParamId,
    /// Shared handle to the parameter (values stay scattered).
    pub param: ParamRef,
    /// Element offset of this member in the bucket's flat buffers.
    pub offset: usize,
    /// Element count of this member.
    pub len: usize,
    /// Logical tensor shape of the member — needed to re-materialize a
    /// ZeRO-3-released value tensor from a gathered flat buffer.
    pub shape: Vec<usize>,
}

/// The lock-protected payload of one bucket.
pub struct BucketData {
    /// Flat gradient buffer covering `grad_range` (every member, in
    /// member order, at full coverage).
    pub grads: Tensor,
    /// `(offset, len)` element range of the bucket that `grads` covers.
    /// Full coverage in ordinary training; a ZeRO-2/3 rank narrows it to
    /// its own shard after the drain-point reduce-scatter + update
    /// ([`BucketData::narrow_grads`]) so steady-state grad residency is
    /// 1/W, and re-widens lazily when the next backward accumulates
    /// ([`BucketData::widen_grads`]). `grads` has length `grad_range.1`.
    pub grad_range: (usize, usize),
    /// Flat optimizer-state buffers (one per state slot), allocated
    /// lazily on the first bucket update, each covering `state_range`.
    pub state: Vec<Tensor>,
    /// `(offset, len)` element range of the bucket that the `state`
    /// tensors cover. Full coverage in ordinary training; a ZeRO rank
    /// narrows it to its own shard so each replica allocates only 1/W of
    /// the optimizer state (see [`crate::comm`]). Every `state` tensor
    /// has length `state_range.1`.
    pub state_range: (usize, usize),
    /// ZeRO-3 shard-resident parameter values: `Some` while the member
    /// value tensors are released (emptied), covering `value_range` of
    /// the bucket arena. `None` while values are materialized in the
    /// per-member tensors (ordinary training, and between the pre-forward
    /// all-gather and the post-update release).
    pub values: Option<Tensor>,
    /// `(offset, len)` range `values` covers when `Some`.
    pub value_range: (usize, usize),
    /// The members, ordered by ascending `offset` with tight packing.
    pub members: Vec<Member>,
    /// Gradient elimination (FORGE-style): when set, the drain-point
    /// update consumes the gradient contribution in place and the grad
    /// buffer is freed outright ([`BucketData::eliminate_grads`]) rather
    /// than narrowed — steady-state grad residency 0, beating ZeRO-2's
    /// 1/W. Set at bucketize time only when effective (backward-fusion,
    /// no gradient accumulation); the next backward re-widens lazily.
    pub elim: bool,
    /// Element dtype of the value/grad arenas (accounting + storage
    /// rounding). Optimizer state stays FP32 master regardless.
    pub dtype: Dtype,
}

impl BucketData {
    /// Total element count of the bucket arena (the spans are tight, so
    /// the last member's end is the total — independent of how narrow
    /// the grad/state/value buffers currently are).
    pub fn num_elems(&self) -> usize {
        self.members.last().map_or(0, |m| m.offset + m.len)
    }

    /// Grow `state` to `n` zero buffers covering `state_range` (no-op if
    /// present).
    pub fn ensure_state(&mut self, n: usize) {
        let len = self.state_range.1;
        while self.state.len() < n {
            self.state.push(Tensor::zeros(&[len]));
        }
    }

    /// Grow `state` to `n` zero buffers that cover at least
    /// `[offset, offset + len)`. On the first allocation the coverage is
    /// set to exactly that range (the ZeRO-1 shard-only allocation);
    /// afterwards the requested range must lie inside the existing
    /// coverage.
    pub fn ensure_state_range(&mut self, n: usize, offset: usize, len: usize) {
        if n == 0 {
            return;
        }
        if self.state.is_empty() {
            self.state_range = (offset, len);
        }
        let (soff, slen) = self.state_range;
        assert!(
            offset >= soff && offset + len <= soff + slen,
            "bucket state covers [{soff}, {}) but the update needs [{offset}, {})",
            soff + slen,
            offset + len
        );
        self.ensure_state(n);
    }

    /// Zero every gradient element outside `[offset, offset + len)`.
    /// After a ZeRO-1 reduce-scatter the complement of a rank's shard
    /// still holds *local, unreduced* gradients; they must be cleared
    /// before the next backward accumulates on top of them. (ZeRO-2/3
    /// instead *free* the complement — [`BucketData::narrow_grads`].)
    pub fn zero_grads_outside(&mut self, offset: usize, len: usize) {
        assert_eq!(
            self.grad_range,
            (0, self.num_elems()),
            "zero_grads_outside over narrowed grads; the complement is already freed"
        );
        let d = self.grads.data_mut();
        for v in &mut d[..offset] {
            *v = 0.0;
        }
        for v in &mut d[offset + len..] {
            *v = 0.0;
        }
    }

    /// Shrink the gradient buffer to `[offset, offset + len)` of the
    /// arena, **preserving** that region's contents and freeing the rest
    /// — the ZeRO-2/3 post-update step that drops steady-state grad
    /// residency to the rank's shard. The range must lie inside the
    /// current coverage.
    pub fn narrow_grads(&mut self, offset: usize, len: usize) {
        let (goff, glen) = self.grad_range;
        assert!(
            offset >= goff && offset + len <= goff + glen,
            "narrow_grads: [{offset}, {}) outside coverage [{goff}, {})",
            offset + len,
            goff + glen
        );
        let kept = self.grads.data()[offset - goff..offset - goff + len].to_vec();
        self.grads = Tensor::from_vec(&[len], kept);
        self.grad_range = (offset, len);
    }

    /// Grow a narrowed gradient buffer back to full arena coverage,
    /// preserving the covered region's contents (normally all-zero —
    /// the update resets consumed gradients). Called lazily when
    /// backward first accumulates into a ZeRO-2/3-narrowed bucket; a
    /// no-op at full coverage.
    pub fn widen_grads(&mut self) {
        let total = self.num_elems();
        if self.grad_range == (0, total) {
            return;
        }
        let (goff, glen) = self.grad_range;
        let mut full = vec![0.0f32; total];
        full[goff..goff + glen].copy_from_slice(self.grads.data());
        self.grads = Tensor::from_vec(&[total], full);
        self.grad_range = (0, total);
    }

    /// Free the gradient buffer outright — coverage `(0, 0)` — after a
    /// drain-point update consumed it. The gradient-elimination
    /// counterpart of [`BucketData::narrow_grads`]: instead of keeping a
    /// 1/W shard, nothing survives the update. The next backward's
    /// [`BucketData::widen_grads`] call restores full zeroed coverage
    /// (widen from `(0, 0)` copies nothing).
    pub fn eliminate_grads(&mut self) {
        self.grads = Tensor::zeros(&[0]);
        self.grad_range = (0, 0);
    }

    /// Round every member's value tensor (and any shard-resident value
    /// buffer) to the bucket dtype's storage precision — a no-op at
    /// FP32. Called after updates write new values so a BF16 arena never
    /// holds a value outside bfloat16. The caller holds the bucket lock;
    /// member locks are taken in member order (the lock-order contract).
    fn round_values_to_dtype(&mut self) {
        if self.dtype == Dtype::F32 {
            return;
        }
        let dtype = self.dtype;
        if let Some(v) = self.values.as_mut() {
            dtype.round_slice(v.data_mut());
        }
        for m in &self.members {
            let mut pd = m.param.data.write().unwrap();
            dtype.round_slice(pd.value.data_mut());
        }
    }

    /// Borrow one member's gradient region (must lie inside the current
    /// grad coverage).
    pub fn grad_slice(&self, member: usize) -> &[f32] {
        let m = &self.members[member];
        let (goff, glen) = self.grad_range;
        assert!(
            m.offset >= goff && m.offset + m.len <= goff + glen,
            "grad_slice: member {member} outside grad coverage [{goff}, {})",
            goff + glen
        );
        &self.grads.data()[m.offset - goff..m.offset - goff + m.len]
    }

    /// Mutably borrow one member's gradient region (must lie inside the
    /// current grad coverage).
    pub fn grad_slice_mut(&mut self, member: usize) -> &mut [f32] {
        let m = &self.members[member];
        let (goff, glen) = self.grad_range;
        assert!(
            m.offset >= goff && m.offset + m.len <= goff + glen,
            "grad_slice_mut: member {member} outside grad coverage [{goff}, {})",
            goff + glen
        );
        let (offset, len) = (m.offset - goff, m.len);
        &mut self.grads.data_mut()[offset..offset + len]
    }

    /// ZeRO-3 release: copy `[offset, offset + len)` of the member value
    /// tensors into a shard-resident flat buffer and empty the member
    /// tensors, dropping per-replica value residency to the shard. The
    /// caller holds the bucket lock; member locks are taken in member
    /// order (the module lock-order contract). No-op if already released.
    pub fn release_values(&mut self, offset: usize, len: usize) {
        if self.values.is_some() {
            return;
        }
        let mut shard = vec![0.0f32; len];
        for m in &self.members {
            let Some((a, b)) = member_overlap(m, offset, len) else {
                // outside the shard: still drop the replica's copy
                let mut pd = m.param.data.write().unwrap();
                pd.value = Tensor::zeros(&[0]);
                continue;
            };
            let mut pd = m.param.data.write().unwrap();
            shard[a - offset..b - offset]
                .copy_from_slice(&pd.value.data()[a - m.offset..b - m.offset]);
            pd.value = Tensor::zeros(&[0]);
        }
        self.values = Some(Tensor::from_vec(&[len], shard));
        self.value_range = (offset, len);
    }

    /// ZeRO-3 materialize: rebuild every member's value tensor (with its
    /// logical shape) from a fully-gathered flat buffer and drop the
    /// shard-resident copy. Inverse of [`BucketData::release_values`];
    /// the caller supplies `full` from the value all-gather.
    pub fn materialize_values(&mut self, full: &[f32]) {
        assert_eq!(full.len(), self.num_elems(), "materialize_values: buffer length");
        for m in &self.members {
            let mut pd = m.param.data.write().unwrap();
            pd.value = Tensor::from_vec(&m.shape, full[m.offset..m.offset + m.len].to_vec());
        }
        self.values = None;
    }
}

/// A bucket cell: lock-protected so a worker thread can run the fused
/// update of one bucket while the main thread continues backward for
/// others (the backward-fusion parallelism claim, now at bucket
/// granularity).
pub struct Bucket {
    /// The bucket payload, guarded by the bucket lock (see the module
    /// docs for the lock order).
    pub data: RwLock<BucketData>,
}

/// Shared handle to a [`Bucket`].
pub type BucketRef = Arc<Bucket>;

/// Mutable, lock-free view of a bucket mid-update: the flat gradient
/// and state buffers plus each member's (scattered) value slice. Built
/// by [`apply_bucket_update`] from the bucket and parameter locks, and
/// consumed by [`Optimizer::update_bucket`].
pub struct BucketViewMut<'a> {
    /// Whole-bucket flat gradient buffer.
    pub grads: &'a mut [f32],
    /// Whole-bucket flat state buffers, one per optimizer state slot.
    pub state: Vec<&'a mut [f32]>,
    /// Member value slices with their spans into the flat buffers.
    pub members: Vec<MemberMut<'a>>,
}

/// One member's mutable view inside a [`BucketViewMut`].
pub struct MemberMut<'a> {
    /// The member's parameter values (its own allocation).
    pub value: &'a mut [f32],
    /// Element offset of the member in the flat buffers.
    pub offset: usize,
    /// Element count of the member.
    pub len: usize,
}

/// Greedily group parameter lengths (in element counts, given in id
/// order) into buckets of at most `cap_bytes` of f32 payload each.
/// Grouping preserves id order, so scattered and bucketed iteration
/// visit scalars in the same sequence — the basis of the bit-exactness
/// guarantee. A single parameter larger than the cap gets its own
/// bucket.
pub fn partition_by_bytes(lens: &[usize], cap_bytes: usize) -> Vec<Vec<usize>> {
    let cap_elems = (cap_bytes / std::mem::size_of::<f32>()).max(1);
    let mut groups: Vec<Vec<usize>> = Vec::new();
    let mut cur: Vec<usize> = Vec::new();
    let mut cur_elems = 0usize;
    for (i, len) in lens.iter().enumerate() {
        if !cur.is_empty() && cur_elems + len > cap_elems {
            groups.push(std::mem::take(&mut cur));
            cur_elems = 0;
        }
        cur.push(i);
        cur_elems += len;
    }
    if !cur.is_empty() {
        groups.push(cur);
    }
    groups
}

/// Build buckets over `params` (indexed by `ParamId`), flattening each
/// group's gradients (and any already-allocated optimizer state) into
/// contiguous backing tensors. Returns the buckets plus a
/// `pid -> (bucket index, member index)` map. The caller (the
/// `ParamStore`) is responsible for retiring the now-redundant
/// per-parameter grad/state allocations.
pub fn build_buckets(
    params: &[ParamRef],
    cap_bytes: usize,
) -> (Vec<BucketRef>, Vec<(usize, usize)>) {
    build_buckets_with(params, cap_bytes, false, Dtype::F32)
}

/// [`build_buckets`] with the gradient-elimination flag and arena dtype
/// stamped on every bucket. Under BF16 the initial member values are
/// rounded to bfloat16 storage precision, so the arena invariant (every
/// stored value representable in the dtype) holds from step 0.
pub fn build_buckets_with(
    params: &[ParamRef],
    cap_bytes: usize,
    elim: bool,
    dtype: Dtype,
) -> (Vec<BucketRef>, Vec<(usize, usize)>) {
    let lens: Vec<usize> = params
        .iter()
        .map(|p| p.data.read().unwrap().value.len())
        .collect();
    let groups = partition_by_bytes(&lens, cap_bytes);
    let mut loc = vec![(0usize, 0usize); params.len()];
    let mut buckets = Vec::with_capacity(groups.len());
    for (bi, group) in groups.iter().enumerate() {
        let guards: Vec<_> = group
            .iter()
            .map(|pid| params[*pid].data.read().unwrap())
            .collect();
        let shapes: Vec<&[usize]> = guards.iter().map(|g| g.value.shape()).collect();
        let layout = FlatLayout::from_shapes(&shapes);
        // flatten current grads (normally all-zero at construction)
        let grad_refs: Vec<&Tensor> = guards.iter().map(|g| &g.grad).collect();
        let grads = layout.pack(&grad_refs);
        // migrate any already-allocated per-parameter state
        let n_state = guards.first().map_or(0, |g| g.state.len());
        assert!(
            guards.iter().all(|g| g.state.len() == n_state),
            "bucketize: members disagree on optimizer state count"
        );
        let state: Vec<Tensor> = (0..n_state)
            .map(|slot| {
                let slot_refs: Vec<&Tensor> = guards.iter().map(|g| &g.state[slot]).collect();
                layout.pack(&slot_refs)
            })
            .collect();
        let members: Vec<Member> = group
            .iter()
            .enumerate()
            .map(|(mi, pid)| {
                loc[*pid] = (bi, mi);
                let span = layout.span(mi);
                Member {
                    pid: *pid,
                    param: Arc::clone(&params[*pid]),
                    offset: span.offset,
                    len: span.len,
                    shape: span.shape.clone(),
                }
            })
            .collect();
        drop(guards);
        let total = grads.len();
        let mut bd = BucketData {
            grads,
            grad_range: (0, total),
            state,
            state_range: (0, total),
            values: None,
            value_range: (0, total),
            members,
            elim,
            dtype,
        };
        bd.round_values_to_dtype();
        bd.dtype.round_slice(bd.grads.data_mut());
        buckets.push(Arc::new(Bucket { data: RwLock::new(bd) }));
    }
    (buckets, loc)
}

/// Run one fused optimizer step over a whole bucket: takes the bucket
/// lock, lazily allocates flat state for `opt`, takes every member's
/// value lock (in member order, after the bucket lock — see the module
/// lock-order contract), and hands the assembled [`BucketViewMut`] to
/// [`Optimizer::update_bucket`]. Shared by the inline schedule paths
/// and the backward-fusion worker pool.
pub fn apply_bucket_update(
    bucket: &Bucket,
    opt: &dyn Optimizer,
    step: u64,
    hp: &Hyper,
    global_scale: f32,
) {
    let mut bd = bucket.data.write().unwrap();
    assert_eq!(
        bd.state_range,
        (0, bd.num_elems()),
        "full bucket update over sharded state; use apply_bucket_update_range"
    );
    assert_eq!(
        bd.grad_range,
        (0, bd.num_elems()),
        "full bucket update over narrowed grads; use apply_bucket_update_range"
    );
    assert!(bd.values.is_none(), "full bucket update over released values");
    bd.ensure_state(opt.num_state());
    let dtype = bd.dtype;
    let BucketData { grads, state, members, .. } = &mut *bd;
    let mut guards: Vec<_> = members
        .iter()
        .map(|m| m.param.data.write().unwrap())
        .collect();
    let mut view = BucketViewMut {
        grads: grads.data_mut(),
        state: state.iter_mut().map(Tensor::data_mut).collect(),
        members: guards
            .iter_mut()
            .zip(members.iter())
            .map(|(g, m)| MemberMut {
                value: g.value.data_mut(),
                offset: m.offset,
                len: m.len,
            })
            .collect(),
    };
    opt.update_bucket(step, &mut view, hp, global_scale);
    if dtype != Dtype::F32 {
        for m in view.members.iter_mut() {
            dtype.round_slice(m.value);
        }
    }
}

/// Consume a bucket's just-reduced gradient contribution in place at
/// the backward-fusion drain point: one fused update pass straight off
/// the contribution, then the grad buffer is freed outright
/// ([`BucketData::eliminate_grads`]) — the FORGE gradient-elimination
/// step. The update math is exactly [`apply_bucket_update`] (same
/// kernel, same order), so the FP32 path is bit-identical to the
/// grad-arena path; the only difference is that nothing of the gradient
/// survives the call, so per-bucket `grad_arena_bytes` reads 0 until
/// the next backward re-widens.
pub fn apply_bucket_update_from_contrib(
    bucket: &Bucket,
    opt: &dyn Optimizer,
    step: u64,
    hp: &Hyper,
    global_scale: f32,
) {
    apply_bucket_update(bucket, opt, step, hp, global_scale);
    bucket.data.write().unwrap().eliminate_grads();
}

/// The intersection of member `m`'s span with `[offset, offset + len)`,
/// as absolute bucket-element bounds `(a, b)` — `None` when disjoint.
/// The single copy of the shard-span ⇄ member-slice clamp arithmetic,
/// shared by the shard update below and the value gather in
/// [`crate::exec::pool`] (the two must never disagree mid-parameter).
pub fn member_overlap(m: &Member, offset: usize, len: usize) -> Option<(usize, usize)> {
    let a = offset.max(m.offset);
    let b = (offset + len).min(m.offset + m.len);
    (a < b).then_some((a, b))
}

/// Run one optimizer step over only `[offset, offset + len)` of a
/// bucket's flat arena — the ZeRO-1 shard update. Walks the members
/// overlapping the range and hands each overlap's value / grad / state
/// sub-slices to the shared [`Optimizer::update_slices`] kernel, so a
/// range update is bit-identical to the same region of a full bucket
/// update (elementwise rules touch every scalar independently).
///
/// Lazily allocates state covering exactly the range when none exists
/// (`BucketData::ensure_state_range`) — this is where a ZeRO-1 replica's
/// optimizer-state footprint drops to its shard. Locks follow the module
/// contract: bucket lock first, then member value locks in member order.
pub fn apply_bucket_update_range(
    bucket: &Bucket,
    opt: &dyn Optimizer,
    step: u64,
    hp: &Hyper,
    global_scale: f32,
    offset: usize,
    len: usize,
) {
    if len == 0 {
        return;
    }
    let mut bd = bucket.data.write().unwrap();
    assert!(
        bd.values.is_none(),
        "range update over released values; use apply_bucket_update_shard_resident"
    );
    bd.ensure_state_range(opt.num_state(), offset, len);
    let soff = bd.state_range.0;
    let (goff, glen) = bd.grad_range;
    assert!(
        offset >= goff && offset + len <= goff + glen,
        "range update [{offset}, {}) outside grad coverage [{goff}, {})",
        offset + len,
        goff + glen
    );
    let dtype = bd.dtype;
    let BucketData { grads, state, members, .. } = &mut *bd;
    let cfg = kernel::global();
    for m in members.iter() {
        let Some((a, b)) = member_overlap(m, offset, len) else { continue };
        let mut pd = m.param.data.write().unwrap();
        let value = &mut pd.value.data_mut()[a - m.offset..b - m.offset];
        let grad = &mut grads.data_mut()[a - goff..b - goff];
        let mut slots: Vec<&mut [f32]> = state
            .iter_mut()
            .map(|s| &mut s.data_mut()[a - soff..b - soff])
            .collect();
        run_update_slices(opt, &cfg, step, value, grad, &mut slots, hp, global_scale);
        dtype.round_slice(value);
    }
}

/// Run one optimizer step over a bucket whose values are ZeRO-3
/// shard-resident ([`BucketData::release_values`]): the update's value /
/// grad / state slices all live in shard-only flat buffers covering
/// exactly the rank's shard, so no member value tensor exists to touch.
/// Bit-identical to the same region of [`apply_bucket_update_range`] —
/// every update rule is elementwise, so where the scalars live (and how
/// the slice is cut) cannot change the math. This is the forward-fusion
/// lazy-update path under ZeRO-3, where values were released right after
/// the previous backward.
pub fn apply_bucket_update_shard_resident(
    bucket: &Bucket,
    opt: &dyn Optimizer,
    step: u64,
    hp: &Hyper,
    global_scale: f32,
) {
    let mut bd = bucket.data.write().unwrap();
    let (off, len) = bd.value_range;
    assert!(bd.values.is_some(), "shard-resident update needs released values");
    if len == 0 {
        return;
    }
    bd.ensure_state_range(opt.num_state(), off, len);
    assert_eq!(
        bd.grad_range,
        (off, len),
        "shard-resident update: grads must be narrowed to the value shard"
    );
    if opt.num_state() > 0 {
        assert_eq!(bd.state_range, (off, len), "shard-resident update: state covers the shard");
    }
    let dtype = bd.dtype;
    let BucketData { grads, state, values, .. } = &mut *bd;
    let value = values.as_mut().expect("released values").data_mut();
    let grad = grads.data_mut();
    let mut slots: Vec<&mut [f32]> = state.iter_mut().map(Tensor::data_mut).collect();
    run_update_slices(opt, &kernel::global(), step, value, grad, &mut slots, hp, global_scale);
    dtype.round_slice(value);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::ParamStore;
    use crate::optim::Sgd;

    #[test]
    fn partition_respects_cap_and_order() {
        // 4-byte floats: cap 40 bytes = 10 elems
        let groups = partition_by_bytes(&[4, 4, 4, 12, 2], 40);
        assert_eq!(groups, vec![vec![0, 1], vec![2], vec![3], vec![4]]);
        // oversized param gets its own bucket
        let groups = partition_by_bytes(&[100, 1], 40);
        assert_eq!(groups, vec![vec![0], vec![1]]);
        // huge cap: one bucket
        let groups = partition_by_bytes(&[3, 3, 3], 1 << 20);
        assert_eq!(groups, vec![vec![0, 1, 2]]);
        assert!(partition_by_bytes(&[], 64).is_empty());
    }

    #[test]
    fn build_buckets_maps_members() {
        let mut store = ParamStore::default();
        store.add("a", Tensor::full(&[2, 2], 1.0));
        store.add("b", Tensor::full(&[3], 2.0));
        store.add("c", Tensor::full(&[5], 3.0));
        // cap 32 bytes = 8 elems: [a(4), b(3)] then [c(5)]
        let (buckets, loc) = build_buckets(&store.params, 32);
        assert_eq!(buckets.len(), 2);
        assert_eq!(loc, vec![(0, 0), (0, 1), (1, 0)]);
        let b0 = buckets[0].data.read().unwrap();
        assert_eq!(b0.num_elems(), 7);
        assert_eq!(b0.members[1].offset, 4);
        assert_eq!(b0.members[1].len, 3);
        assert!(b0.grads.data().iter().all(|g| *g == 0.0));
        assert!(b0.state.is_empty());
    }

    #[test]
    fn apply_bucket_update_runs_the_rule() {
        let mut store = ParamStore::default();
        store.add("a", Tensor::full(&[2], 1.0));
        store.add("b", Tensor::full(&[3], 2.0));
        let (buckets, _) = build_buckets(&store.params, 1 << 20);
        {
            let mut bd = buckets[0].data.write().unwrap();
            bd.grads = Tensor::full(&[5], 1.0);
        }
        let hp = Hyper { lr: 0.5, weight_decay: 0.0, ..Hyper::default() };
        apply_bucket_update(&buckets[0], &Sgd, 1, &hp, 1.0);
        let bd = buckets[0].data.read().unwrap();
        assert!(bd.grads.data().iter().all(|g| *g == 0.0), "grads reset");
        assert_eq!(store.params[0].data.read().unwrap().value.data(), &[0.5, 0.5]);
        assert_eq!(store.params[1].data.read().unwrap().value.data(), &[1.5, 1.5, 1.5]);
    }

    /// Two disjoint range updates must equal one full update exactly, and
    /// a range that splits a member mid-tensor must still land right.
    #[test]
    fn range_updates_compose_to_full_update() {
        use crate::optim::SgdMomentum;
        let mk = || {
            let mut store = ParamStore::default();
            store.add("a", Tensor::full(&[3], 1.0));
            store.add("b", Tensor::full(&[5], 2.0));
            let (buckets, _) = build_buckets(&store.params, 1 << 20);
            buckets[0].data.write().unwrap().grads =
                Tensor::from_vec(&[8], (1..=8).map(|i| i as f32 * 0.1).collect());
            (store, buckets)
        };
        let hp = Hyper { lr: 0.5, weight_decay: 0.0, ..Hyper::default() };
        let (full_store, full_buckets) = mk();
        apply_bucket_update(&full_buckets[0], &SgdMomentum, 1, &hp, 1.0);
        let (part_store, part_buckets) = mk();
        // split mid-member "b": [0, 5) then [5, 8)
        apply_bucket_update_range(&part_buckets[0], &SgdMomentum, 1, &hp, 1.0, 0, 5);
        // second range: state for [5, 8) not covered by the first alloc —
        // use a fresh bucket to model the other rank
        let (other_store, other_buckets) = mk();
        apply_bucket_update_range(&other_buckets[0], &SgdMomentum, 1, &hp, 1.0, 5, 3);
        for pid in 0..2 {
            let f = full_store.params[pid].data.read().unwrap();
            let p = part_store.params[pid].data.read().unwrap();
            let o = other_store.params[pid].data.read().unwrap();
            for (i, fv) in f.value.data().iter().enumerate() {
                // bucket offsets: param 0 -> [0,3), param 1 -> [3,8)
                let flat = if pid == 0 { i } else { 3 + i };
                let got = if flat < 5 { p.value.data()[i] } else { o.value.data()[i] };
                assert_eq!(*fv, got, "param {pid} elem {i} bit-identical");
            }
        }
        // shard-only state allocation: rank covering [0,5) holds 5 elems
        let bd = part_buckets[0].data.read().unwrap();
        assert_eq!(bd.state_range, (0, 5));
        assert_eq!(bd.state[0].len(), 5);
        let bd = other_buckets[0].data.read().unwrap();
        assert_eq!(bd.state_range, (5, 3));
        assert_eq!(bd.state[0].len(), 3);
    }

    #[test]
    fn zero_grads_outside_clears_complement() {
        let mut store = ParamStore::default();
        store.add("a", Tensor::full(&[6], 1.0));
        let (buckets, _) = build_buckets(&store.params, 1 << 20);
        {
            let mut bd = buckets[0].data.write().unwrap();
            bd.grads = Tensor::full(&[6], 2.0);
            bd.zero_grads_outside(2, 3);
            assert_eq!(bd.grads.data(), &[0.0, 0.0, 2.0, 2.0, 2.0, 0.0]);
        }
    }

    /// ZeRO-2 grad lifecycle: narrow preserves the shard slice and frees
    /// the rest; widen restores full coverage preserving the shard.
    #[test]
    fn narrow_and_widen_grads_roundtrip() {
        let mut store = ParamStore::default();
        store.add("a", Tensor::full(&[6], 1.0));
        let (buckets, _) = build_buckets(&store.params, 1 << 20);
        let mut bd = buckets[0].data.write().unwrap();
        bd.grads = Tensor::from_vec(&[6], (0..6).map(|i| i as f32).collect());
        bd.narrow_grads(2, 3);
        assert_eq!(bd.grad_range, (2, 3));
        assert_eq!(bd.grads.data(), &[2.0, 3.0, 4.0]);
        bd.widen_grads();
        assert_eq!(bd.grad_range, (0, 6));
        assert_eq!(bd.grads.data(), &[0.0, 0.0, 2.0, 3.0, 4.0, 0.0]);
        bd.widen_grads(); // idempotent
        assert_eq!(bd.grad_range, (0, 6));
    }

    /// ZeRO-3 value lifecycle: release extracts the shard and empties
    /// member tensors; materialize rebuilds them with their shapes.
    #[test]
    fn release_and_materialize_values_roundtrip() {
        let mut store = ParamStore::default();
        store.add("a", Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]));
        store.add("b", Tensor::from_vec(&[3], vec![5.0, 6.0, 7.0]));
        let (buckets, _) = build_buckets(&store.params, 1 << 20);
        let mut bd = buckets[0].data.write().unwrap();
        // shard [2, 5): straddles both members mid-tensor
        bd.release_values(2, 3);
        assert_eq!(bd.value_range, (2, 3));
        assert_eq!(bd.values.as_ref().unwrap().data(), &[3.0, 4.0, 5.0]);
        assert_eq!(store.params[0].data.read().unwrap().value.len(), 0, "released");
        assert_eq!(store.params[1].data.read().unwrap().value.len(), 0, "released");
        bd.release_values(2, 3); // idempotent
        // a gathered full buffer rebuilds the members, shapes intact
        let full: Vec<f32> = (10..17).map(|i| i as f32).collect();
        bd.materialize_values(&full);
        assert!(bd.values.is_none());
        let p0 = store.params[0].data.read().unwrap();
        assert_eq!(p0.value.shape(), &[2, 2]);
        assert_eq!(p0.value.data(), &[10.0, 11.0, 12.0, 13.0]);
        assert_eq!(store.params[1].data.read().unwrap().value.data(), &[14.0, 15.0, 16.0]);
    }

    /// A shard-resident update (values released, grads/state narrowed)
    /// must be bit-identical to the same range of a member-resident
    /// range update.
    #[test]
    fn shard_resident_update_matches_range_update() {
        use crate::optim::SgdMomentum;
        let hp = Hyper { lr: 0.5, weight_decay: 0.0, ..Hyper::default() };
        let grads: Vec<f32> = (1..=8).map(|i| i as f32 * 0.1).collect();
        let mk = || {
            let mut store = ParamStore::default();
            store.add("a", Tensor::full(&[3], 1.0));
            store.add("b", Tensor::full(&[5], 2.0));
            let (buckets, _) = build_buckets(&store.params, 1 << 20);
            buckets[0].data.write().unwrap().grads = Tensor::from_vec(&[8], grads.clone());
            (store, buckets)
        };
        // reference: member-resident range update over [2, 6)
        let (ref_store, ref_buckets) = mk();
        apply_bucket_update_range(&ref_buckets[0], &SgdMomentum, 1, &hp, 1.0, 2, 4);
        // shard-resident twin: release values + narrow grads first
        let (_store, buckets) = mk();
        {
            let mut bd = buckets[0].data.write().unwrap();
            bd.release_values(2, 4);
            bd.narrow_grads(2, 4);
        }
        apply_bucket_update_shard_resident(&buckets[0], &SgdMomentum, 1, &hp, 1.0);
        let bd = buckets[0].data.read().unwrap();
        let vals = bd.values.as_ref().unwrap().data();
        let r0 = ref_store.params[0].data.read().unwrap();
        let r1 = ref_store.params[1].data.read().unwrap();
        // arena [2, 6) = member a's [2, 3) then member b's [0, 3)
        assert_eq!(vals[0], r0.value.data()[2]);
        assert_eq!(&vals[1..], &r1.value.data()[..3]);
        assert!(bd.grads.data().iter().all(|g| *g == 0.0), "shard grads reset");
        assert_eq!(bd.state_range, (2, 4));
        assert_eq!(bd.state[0].len(), 4, "state allocated shard-only");
    }

    /// Gradient elimination: a from-contrib update must leave values
    /// bit-identical to the arena-path update, with the grad buffer
    /// freed outright; the next widen restores full zeroed coverage.
    #[test]
    fn from_contrib_update_matches_arena_path_and_frees_grads() {
        use crate::optim::Adam;
        let grads: Vec<f32> = (1..=5).map(|i| i as f32 * 0.3).collect();
        let mk = || {
            let mut store = ParamStore::default();
            store.add("a", Tensor::full(&[2], 1.0));
            store.add("b", Tensor::full(&[3], 2.0));
            let (buckets, _) = build_buckets(&store.params, 1 << 20);
            buckets[0].data.write().unwrap().grads = Tensor::from_vec(&[5], grads.clone());
            (store, buckets)
        };
        let hp = Hyper { lr: 0.1, weight_decay: 0.01, ..Hyper::default() };
        let (arena_store, arena_buckets) = mk();
        apply_bucket_update(&arena_buckets[0], &Adam, 1, &hp, 1.0);
        let (elim_store, elim_buckets) = mk();
        apply_bucket_update_from_contrib(&elim_buckets[0], &Adam, 1, &hp, 1.0);
        for pid in 0..2 {
            let a = arena_store.params[pid].data.read().unwrap();
            let e = elim_store.params[pid].data.read().unwrap();
            assert_eq!(a.value.data(), e.value.data(), "param {pid} bit-identical");
        }
        let mut bd = elim_buckets[0].data.write().unwrap();
        assert_eq!(bd.grad_range, (0, 0), "grad buffer freed");
        assert_eq!(bd.grads.len(), 0);
        bd.widen_grads();
        assert_eq!(bd.grad_range, (0, 5), "widen from empty restores coverage");
        assert!(bd.grads.data().iter().all(|g| *g == 0.0));
    }

    /// BF16 buckets: every value written by an update is representable
    /// in bfloat16, and initial values are rounded at bucketize.
    #[test]
    fn bf16_buckets_round_values_at_store_points() {
        use crate::optim::SgdMomentum;
        use crate::tensor::dtype::bf16_round;
        let mut store = ParamStore::default();
        store.add("a", Tensor::full(&[3], 0.1)); // 0.1 not bf16-representable
        store.add("b", Tensor::full(&[5], 2.0));
        let (buckets, _) = build_buckets_with(&store.params, 1 << 20, false, Dtype::Bf16);
        {
            let p0 = store.params[0].data.read().unwrap();
            assert!(
                p0.value.data().iter().all(|v| bf16_round(*v) == *v),
                "initial values rounded to bf16 storage"
            );
            assert_eq!(p0.value.data()[0], bf16_round(0.1));
        }
        buckets[0].data.write().unwrap().grads =
            Tensor::from_vec(&[8], (1..=8).map(|i| i as f32 * 0.07).collect());
        let hp = Hyper { lr: 0.5, weight_decay: 0.0, ..Hyper::default() };
        apply_bucket_update(&buckets[0], &SgdMomentum, 1, &hp, 1.0);
        for p in &store.params {
            let pd = p.data.read().unwrap();
            assert!(
                pd.value.data().iter().all(|v| bf16_round(*v) == *v),
                "post-update values representable in bf16"
            );
        }
    }

    #[test]
    #[should_panic(expected = "the update needs")]
    fn range_update_outside_coverage_panics() {
        let mut store = ParamStore::default();
        store.add("a", Tensor::full(&[8], 1.0));
        let (buckets, _) = build_buckets(&store.params, 1 << 20);
        let hp = Hyper::default();
        use crate::optim::SgdMomentum;
        apply_bucket_update_range(&buckets[0], &SgdMomentum, 1, &hp, 1.0, 0, 4);
        // coverage is now [0, 4): updating [4, 8) must fail fast
        apply_bucket_update_range(&buckets[0], &SgdMomentum, 1, &hp, 1.0, 4, 4);
    }
}
