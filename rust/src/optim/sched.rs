//! Learning-rate schedules. They interact subtly with the fusion
//! schedules: forward-fusion applies step t's update during step t+1's
//! forward, so the LR must be evaluated at the *gradient's* step index,
//! not the wallclock step — the executor threads the right index through,
//! and the equivalence tests in `exec` would catch any drift.

/// A learning-rate schedule over 1-based step indices.
pub trait LrSchedule: Send + Sync {
    fn lr(&self, step: u64) -> f32;
    fn name(&self) -> &'static str;
}

/// Constant LR.
pub struct ConstantLr(pub f32);

impl LrSchedule for ConstantLr {
    fn lr(&self, _step: u64) -> f32 {
        self.0
    }
    fn name(&self) -> &'static str {
        "constant"
    }
}

/// Linear warmup to `peak` over `warmup` steps, then cosine decay to
/// `floor` at `total` steps (transformer-style).
pub struct WarmupCosine {
    pub peak: f32,
    pub floor: f32,
    pub warmup: u64,
    pub total: u64,
}

impl LrSchedule for WarmupCosine {
    fn lr(&self, step: u64) -> f32 {
        if step <= self.warmup {
            return self.peak * step as f32 / self.warmup.max(1) as f32;
        }
        let t = (step - self.warmup) as f32 / (self.total - self.warmup).max(1) as f32;
        let t = t.min(1.0);
        self.floor + 0.5 * (self.peak - self.floor) * (1.0 + (std::f32::consts::PI * t).cos())
    }
    fn name(&self) -> &'static str {
        "warmup_cosine"
    }
}

/// Step decay: multiply by `gamma` every `every` steps.
pub struct StepDecay {
    pub base: f32,
    pub gamma: f32,
    pub every: u64,
}

impl LrSchedule for StepDecay {
    fn lr(&self, step: u64) -> f32 {
        self.base * self.gamma.powi((step / self.every.max(1)) as i32)
    }
    fn name(&self) -> &'static str {
        "step_decay"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_is_constant() {
        let s = ConstantLr(0.1);
        assert_eq!(s.lr(1), 0.1);
        assert_eq!(s.lr(1000), 0.1);
    }

    #[test]
    fn warmup_ramps_then_decays() {
        let s = WarmupCosine { peak: 1.0, floor: 0.1, warmup: 10, total: 110 };
        assert!(s.lr(1) < s.lr(5));
        assert!((s.lr(10) - 1.0).abs() < 1e-6, "peak at end of warmup");
        assert!(s.lr(60) < 1.0 && s.lr(60) > 0.1);
        assert!((s.lr(110) - 0.1).abs() < 1e-5, "floor at total");
        assert!((s.lr(500) - 0.1).abs() < 1e-5, "clamped after total");
    }

    #[test]
    fn step_decay_steps() {
        let s = StepDecay { base: 1.0, gamma: 0.5, every: 10 };
        assert_eq!(s.lr(5), 1.0);
        assert_eq!(s.lr(10), 0.5);
        assert_eq!(s.lr(25), 0.25);
    }
}
