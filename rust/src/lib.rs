//! optfuse — reproduction of "Optimizer Fusion: Efficient Training with
//! Better Locality and Parallelism" (Jiang et al., 2021).
//!
//! Three-layer architecture (see ARCHITECTURE.md for the full map):
//! * L3 (this crate): eager-execution training engine whose scheduler
//!   implements the paper's baseline / forward-fusion / backward-fusion,
//!   over either scattered per-parameter storage or bucketed flat
//!   storage ([`optim::bucket`]).
//! * L2/L1 (python/, build-time only): JAX model + Pallas fused kernels,
//!   AOT-lowered to HLO text and executed via PJRT in `runtime`.

#![warn(missing_docs)]

pub mod checkpoint;
pub mod comm;
pub mod config;
pub mod data;
pub mod ddp;
pub mod exec;
pub mod graph;
pub mod memsim;
pub mod models;
pub mod ops;
pub mod optim;
pub mod runtime;
pub mod train;
pub mod tensor;
pub mod util;
