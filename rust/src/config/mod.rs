//! Minimal CLI flag parser (the offline crate set has no clap).
//! Supports `--key value`, `--key=value`, and bare subcommands.

use std::collections::BTreeMap;

/// Parsed command line: a subcommand plus `--key value` flags.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub command: Option<String>,
    flags: BTreeMap<String, String>,
    positional: Vec<String>,
}

impl Args {
    pub fn parse<I: IntoIterator<Item = String>>(items: I) -> Self {
        let mut out = Args::default();
        let mut it = items.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.flags.insert(stripped.to_string(), v);
                } else {
                    out.flags.insert(stripped.to_string(), "true".to_string());
                }
            } else if out.command.is_none() {
                out.command = Some(tok);
            } else {
                out.positional.push(tok);
            }
        }
        out
    }

    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn f32_or(&self, key: &str, default: f32) -> f32 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn flag(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("train --model mlp --batch 32 --verbose --lr=0.01 extra");
        assert_eq!(a.command.as_deref(), Some("train"));
        assert_eq!(a.get("model"), Some("mlp"));
        assert_eq!(a.usize_or("batch", 0), 32);
        assert!(a.flag("verbose"));
        assert!((a.f32_or("lr", 0.0) - 0.01).abs() < 1e-9);
        assert_eq!(a.positional(), &["extra".to_string()]);
    }

    #[test]
    fn defaults() {
        let a = parse("bench");
        assert_eq!(a.str_or("model", "mlp"), "mlp");
        assert_eq!(a.usize_or("steps", 10), 10);
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse("x --a --b 3");
        assert!(a.flag("a"));
        assert_eq!(a.usize_or("b", 0), 3);
    }
}
