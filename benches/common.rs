//! Shared helpers for the paper-reproduction bench harness.
//! Each bench target is `harness = false` and prints the rows/series of
//! one paper table or figure (see DESIGN.md §5 for the index).

#![allow(dead_code)]

use optfuse::data::image_batch;
use optfuse::exec::{ExecConfig, Executor};
use optfuse::graph::{Graph, ScheduleKind};
use optfuse::memsim::{self, spec::NetSpec, spec::OptSpec, Machine};
use optfuse::optim::{self, Hyper};
use optfuse::train::{self, RunReport};
use optfuse::util::XorShiftRng;

/// CI smoke mode for the perf harnesses: `--smoke` on the command line
/// or `OPTFUSE_BENCH_SMOKE` set to anything but empty/`0`. Reduced
/// sweep sizes so the `bench-smoke` CI job stays cheap on small runners.
pub fn smoke_mode() -> bool {
    if std::env::args().any(|a| a == "--smoke") {
        return true;
    }
    matches!(std::env::var("OPTFUSE_BENCH_SMOKE"), Ok(v) if !v.is_empty() && v != "0")
}

pub fn header(title: &str, paper_says: &str) {
    println!("\n==================================================================");
    println!("{title}");
    println!("paper reference: {paper_says}");
    println!("==================================================================");
}

/// Measured wallclock run of a small real model on this host.
pub fn measure(
    build: fn(u64) -> Graph,
    kind: ScheduleKind,
    opt: &str,
    batch: usize,
    steps: usize,
    threads: usize,
) -> RunReport {
    let mut ex = Executor::new(
        build(42),
        optim::by_name(opt).unwrap(),
        Hyper { lr: 1e-3, ..Hyper::default() },
        ExecConfig { schedule: kind, threads, race_guard: true, ..Default::default() },
    )
    .unwrap();
    let mut rng = XorShiftRng::new(9);
    train::run(&mut ex, steps, 2, |_| image_batch(batch, 3, 16, 16, 10, &mut rng))
}

/// Simulated speedups (FF, BF) of `net` at `batch` on `machine`.
pub fn sim_speedups(m: &Machine, net: &NetSpec, opt: &OptSpec, batch: usize) -> (f64, f64, f64) {
    let base = memsim::simulate(m, net, opt, batch, ScheduleKind::Baseline);
    let ff = memsim::simulate(m, net, opt, batch, ScheduleKind::ForwardFusion);
    let bf = memsim::simulate(m, net, opt, batch, ScheduleKind::BackwardFusion);
    (base.total_s, base.total_s / ff.total_s, base.total_s / bf.total_s)
}

/// Render a simple ASCII series for figure-style output.
pub fn ascii_series(label: &str, xs: &[f64], ys: &[f64]) {
    let ymax = ys.iter().cloned().fold(f64::MIN, f64::max);
    let ymin = ys.iter().cloned().fold(f64::MAX, f64::min).min(1.0);
    println!("  {label}:");
    for (x, y) in xs.iter().zip(ys.iter()) {
        let frac = if ymax > ymin { (y - ymin) / (ymax - ymin) } else { 0.0 };
        let bar = "#".repeat(1 + (frac * 40.0) as usize);
        println!("    x={x:>8.1}  y={y:>7.3}  {bar}");
    }
}
