//! Fig. 7: speedup vs the optimizer's share of iteration runtime, across
//! optimizers (SGD … Adadelta) on MobileNetV2, bs=32.
//!
//! Paper claim: the more runtime-costly the optimizer, the higher the
//! speedup (increasing trend in the ratio→speedup scatter).

#[path = "common.rs"]
mod common;

use optfuse::graph::ScheduleKind;
use optfuse::memsim::{self, machines, spec::OptSpec, zoo};
use optfuse::models;

fn main() {
    common::header(
        "Fig. 7 — speedup vs optimizer-runtime ratio (MobileNetV2 bs=32)",
        "increasing trend: costlier optimizers benefit more; weight decay everywhere",
    );

    let m = machines::titan_xp();
    let net = zoo::mobilenet_v2();

    println!("\nsimulated (memsim, TITAN Xp):");
    println!("  optimizer       opt/iter ratio    FF speedup   BF speedup");
    let mut pts = Vec::new();
    for name in OptSpec::ALL {
        let opt = OptSpec::by_name(name).unwrap();
        let base = memsim::simulate(&m, &net, &opt, 32, optfuse::graph::ScheduleKind::Baseline);
        let ratio = base.optimizer_s / base.total_s;
        let (_, ff, bf) = common::sim_speedups(&m, &net, &opt, 32);
        println!("  {name:<14} {:>10.1}%     {ff:>8.3}     {bf:>8.3}", ratio * 100.0);
        pts.push((ratio, bf));
    }
    // monotone-ish trend: Spearman-style check on (ratio, speedup)
    pts.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    let increasing = pts.windows(2).filter(|w| w[1].1 >= w[0].1 - 0.01).count();
    println!(
        "\n  trend: {increasing}/{} adjacent pairs non-decreasing (ratio ↑ ⇒ speedup ↑)",
        pts.len() - 1
    );
    assert!(increasing >= pts.len() - 2, "Fig. 7 trend must hold");
    assert!(
        pts.last().unwrap().1 > pts.first().unwrap().1,
        "costliest optimizer must gain most"
    );

    // measured: optimizer-stage cost ratio on this host in the
    // parameter-heavy regime (wide_mlp, bs=2) — the measurable analogue
    println!("\nmeasured on this host (wide_mlp bs=2, baseline opt-stage share):");
    for name in ["sgd", "sgd_momentum", "adagrad", "rmsprop", "adam", "adamw", "adadelta"] {
        let r = common::measure(models::wide_mlp, ScheduleKind::Baseline, name, 2, 8, 0);
        let (_, _, o) = r.breakdown_ms();
        println!("  {name:<14} opt {o:>6.2} ms  ({:>5.1}% of iter)", 100.0 * o / r.iter_ms());
    }
    println!("\nFig. 7 reproduced (shape) ✓");
}
