//! Fig. 3: training-time breakdown of MobileNetV2, mini-batch 32, under
//! baseline / forward-fusion / backward-fusion.
//!
//! Paper numbers (TITAN Xp): baseline ≈ 98.8 ms with optimizer 16.70 ms;
//! BF grows backward by only 3.32 ms while removing the whole optimizer
//! stage; throughput +12% (FF) and +16% (BF).

#[path = "common.rs"]
mod common;

use optfuse::graph::ScheduleKind;
use optfuse::memsim::{self, machines, spec::OptSpec, zoo};
use optfuse::models;

fn main() {
    common::header(
        "Fig. 3 — time breakdown, MobileNetV2 bs=32 (Adam+wd)",
        "baseline fwd/bwd/opt ≈ 30/50/16.7 ms; FF +12%, BF +16% throughput; BF bwd +3.32 ms",
    );

    // ---- simulated (full-scale model on the paper's machine) ----
    println!("\nsimulated (memsim, TITAN Xp):");
    let m = machines::titan_xp();
    let net = zoo::mobilenet_v2();
    let opt = OptSpec::adam();
    let base = memsim::simulate(&m, &net, &opt, 32, ScheduleKind::Baseline);
    let mut bwd_growth_ms = 0.0;
    for kind in ScheduleKind::ALL {
        let r = memsim::simulate(&m, &net, &opt, 32, kind);
        let (f, b, o, t) = r.ms();
        println!(
            "  {:<16} fwd {f:7.2}  bwd {b:7.2}  opt {o:7.2}  total {t:7.2} ms   throughput x{:.3}",
            kind.label(),
            base.total_s / r.total_s
        );
        if kind == ScheduleKind::BackwardFusion {
            bwd_growth_ms = b - base.backward_s * 1e3;
        }
    }
    let opt_ms = base.optimizer_s * 1e3;
    println!(
        "\n  BF backward grew {bwd_growth_ms:.2} ms — much smaller than the optimizer stage it \
         replaced ({opt_ms:.2} ms), as in the paper (3.32 vs 16.70 ms)"
    );
    assert!(bwd_growth_ms < 0.5 * opt_ms);

    // ---- measured (small real model on this host) ----
    println!("\nmeasured on this host (mobilenet_v2_ish, bs=32, single-core CPU):");
    println!("  (1-core host: parallelism gains are sim-only; this validates the breakdown shape)");
    let base = common::measure(models::mobilenet_v2_ish, ScheduleKind::Baseline, "adam", 32, 6, 0);
    for kind in ScheduleKind::ALL {
        let r = common::measure(models::mobilenet_v2_ish, kind, "adam", 32, 6, 0);
        let (f, b, o) = r.breakdown_ms();
        println!(
            "  {:<16} fwd {f:7.2}  bwd {b:7.2}  opt {o:7.2}  total {:7.2} ms   x{:.3}",
            kind.label(),
            r.iter_ms(),
            base.iter_ms() / r.iter_ms()
        );
        assert_eq!(r.losses, base.losses, "schedule must not change training");
    }
    println!("\nFig. 3 reproduced (shape) ✓");
}
