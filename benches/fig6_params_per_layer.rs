//! Fig. 6: speedup trend vs average parameters per layer, mini-batch 32.
//!
//! Paper claim: fewer parameters per layer → more locality to exploit →
//! higher speedup (MobileNetV2 at one end, VGG19_BN at the other).

#[path = "common.rs"]
mod common;

use optfuse::graph::ScheduleKind;
use optfuse::memsim::{machines, spec::OptSpec, zoo};
use optfuse::models;

fn main() {
    common::header(
        "Fig. 6 — speedup vs avg params/layer (bs=32)",
        "fewer params per layer ⇒ higher speedup; VGG19_BN ≈ 1, MobileNetV2 highest",
    );

    let m = machines::titan_xp();
    let opt = OptSpec::adam();

    println!("\nsimulated (memsim, TITAN Xp, BF):");
    println!("  model            avg params/layer     BF speedup");
    let mut pts: Vec<(f64, f64, String)> = Vec::new();
    for net in zoo::fig5_models() {
        let (_, _, bf) = common::sim_speedups(&m, &net, &opt, 32);
        println!(
            "  {:<16} {:>14.0}       {bf:>8.3}",
            net.name,
            net.avg_params_per_layer()
        );
        pts.push((net.avg_params_per_layer(), bf, net.name.clone()));
    }
    // trend: the sparsest-layer model must beat the densest by a clear margin
    pts.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    let (first, last) = (&pts[0], &pts[pts.len() - 1]);
    println!(
        "\n  {} ({:.0}/layer) x{:.3}  >  {} ({:.0}/layer) x{:.3}",
        first.2, first.0, first.1, last.2, last.0, last.1
    );
    assert!(first.1 > last.1 + 0.05, "Fig. 6 trend must hold");

    // measured: small real models on this host (ordering of measured
    // optimizer-stage share follows the same params/layer trend)
    println!("\nmeasured on this host (optimizer-stage share of iteration, baseline, bs=4):");
    println!("  model              params/layer   opt share");
    for (name, build) in [
        ("mobilenet_v2_ish", models::mobilenet_v2_ish as fn(u64) -> optfuse::graph::Graph),
        ("densenet_ish", models::densenet_ish),
        ("resnet_ish", models::resnet_ish),
        ("vgg_ish", models::vgg_ish),
    ] {
        let g = build(1);
        let ppl = g.avg_params_per_layer();
        let r = common::measure(build, ScheduleKind::Baseline, "adam", 4, 6, 0);
        let (_, _, o) = r.breakdown_ms();
        println!("  {name:<18} {ppl:>10.0}   {:>6.2}%", 100.0 * o / r.iter_ms());
    }
    println!("\nFig. 6 reproduced (shape) ✓");
}
