//! §C.5: distributed data parallel — "the training speedup with DDP is
//! similar to that on a single GPU". We run the DDP simulation with both
//! schedules, check math-equivalence, report iteration time and
//! all-reduce traffic, and compare the schedule speedup against the
//! single-worker case.

#[path = "common.rs"]
mod common;

use optfuse::data::image_batch;
use optfuse::ddp::{train_ddp, DdpConfig};
use optfuse::graph::ScheduleKind;
use optfuse::models;
use optfuse::optim::{self, Hyper};
use optfuse::util::XorShiftRng;

fn run(world: usize, schedule: ScheduleKind, steps: usize) -> optfuse::ddp::DdpReport {
    train_ddp(
        || models::deep_mlp(3),
        || optim::by_name("adam").unwrap(),
        Hyper::default(),
        DdpConfig {
            world,
            schedule,
            steps,
            bucket_cap_bytes: None,
            local_batch_maker: Box::new(move |rank, step| {
                let mut rng = XorShiftRng::new(((rank as u64) << 32) | step as u64);
                image_batch(4, 3, 16, 16, 10, &mut rng)
            }),
        },
    )
}

fn main() {
    common::header(
        "§C.5 — DDP training with the fusion schedules",
        "optimizer managed per-replica after all-reduce; speedup similar to single-GPU",
    );

    let steps = 4;
    println!("\n  world  schedule          iter ms    comm MiB    final loss");
    let mut final_losses = Vec::new();
    for world in [1usize, 2, 4] {
        for schedule in [ScheduleKind::Baseline, ScheduleKind::BackwardFusion] {
            let r = run(world, schedule, steps);
            println!(
                "  {world:>5}  {:<16} {:>8.2}   {:>8.2}    {:.4}",
                schedule.label(),
                r.iter_ms,
                r.comm_bytes as f64 / (1 << 20) as f64,
                r.losses.last().unwrap()
            );
            final_losses.push((world, schedule, *r.losses.last().unwrap()));
        }
    }
    // math equivalence: schedules agree at every world size
    for world in [1usize, 2, 4] {
        let ls: Vec<f32> = final_losses
            .iter()
            .filter(|(w, _, _)| *w == world)
            .map(|(_, _, l)| *l)
            .collect();
        assert!(
            (ls[0] - ls[1]).abs() < 1e-6,
            "world {world}: schedules must produce identical training"
        );
    }
    // comm volume scales with world size (2 copies per rank per reduce)
    let comm1 = run(1, ScheduleKind::Baseline, 1).comm_bytes;
    let comm4 = run(4, ScheduleKind::Baseline, 1).comm_bytes;
    assert!(comm4 > 3 * comm1, "all-reduce traffic grows with world size");
    println!(
        "\n  schedule-equivalence holds at every world size ✓ (single-core host: \
         wallclock scaling is contended; traffic accounting is exact)\n§C.5 reproduced ✓"
    );
}
