//! §C.5: distributed data parallel — "the training speedup with DDP is
//! similar to that on a single GPU". The harness sweeps the comm axes:
//! schedule (baseline vs backward-fusion), storage (scattered vs
//! bucketed collectives), ZeRO shard stage (none/zero1/zero2/zero3),
//! backward-fusion overlap threads on/off, and the collective
//! **algorithm** (flat staged sessions vs chunked ring vs binomial
//! tree vs the two-tier hierarchical composition, plus the `--algo
//! auto` per-bucket planner measured against every manual choice) —
//! reporting iteration time, communicator traffic (bytes *and* hop
//! legs), rounds per step, the measured comm/compute overlap fraction,
//! and the per-replica arena footprints. The shard-stage
//! section prints the per-stage peak-memory table (grads / values /
//! optimizer state per replica) and asserts it equals
//! `memsim::stage_memory`'s closed form exactly; the algo section
//! compares the measured per-step wire accounting against
//! `memsim::simulate_ddp`'s prediction — the two must agree exactly
//! (the cluster-scaling claim of the comm model, asserted for every
//! algorithm); and a calibration section least-squares-fits the
//! `shared_mem` interconnect's hop latency / link bandwidth from the
//! measured blocked time (`machines::fit_interconnect`) instead of the
//! hand-picked constants.
//!
//! The math-equivalence assertions that used to live here (schedules
//! agree at every world size; world=W bit-equal to a single process;
//! sharded ⇄ unsharded bit-equal) moved to
//! `rust/tests/integration_ddp.rs` and
//! `rust/tests/integration_comm_model.rs`, where `cargo test` actually
//! runs them in CI; this harness keeps perf-shaped sanity checks.
//!
//! Smoke mode (`--smoke` or `OPTFUSE_BENCH_SMOKE=1`): reduced worlds and
//! step counts so CI can run the harness on every PR and upload the
//! printed tables as a build artifact (paper-figure output rot shows up
//! in the diff instead of at the next manual run).

#[path = "common.rs"]
mod common;

use optfuse::comm::{AlgoSelect, CommAlgo, ShardStage, WireCost};
use optfuse::data::image_batch;
use optfuse::ddp::{train_ddp, DdpConfig, DdpReport};
use optfuse::exec::kernel::{KernelConfig, KernelMode};
use optfuse::graph::{Graph, ScheduleKind, Src};
use optfuse::memsim::{machines, stage_memory, stage_memory_opts, CollOp};
use optfuse::models;
use optfuse::ops::activation::Relu;
use optfuse::ops::dense::Linear;
use optfuse::ops::loss::MseLoss;
use optfuse::optim::{self, Hyper};
use optfuse::tensor::dtype::Dtype;
use optfuse::tensor::Tensor;
use optfuse::util::XorShiftRng;

struct Axis {
    label: &'static str,
    schedule: ScheduleKind,
    bucket_cap: Option<usize>,
    stage: ShardStage,
    overlap: usize,
}

const CAP: usize = 1 << 20;

fn run(world: usize, algo: AlgoSelect, axis: &Axis, steps: usize) -> DdpReport {
    run_kernel(world, algo, axis, steps, KernelConfig::default())
}

fn run_kernel(
    world: usize,
    algo: AlgoSelect,
    axis: &Axis,
    steps: usize,
    kernel: KernelConfig,
) -> DdpReport {
    run_topo(world, 0, algo, axis, steps, 0, None, kernel, false, Dtype::F32)
}

fn run_precision(
    world: usize,
    algo: AlgoSelect,
    axis: &Axis,
    steps: usize,
    grad_elim: bool,
    dtype: Dtype,
) -> DdpReport {
    run_topo(world, 0, algo, axis, steps, 0, None, KernelConfig::default(), grad_elim, dtype)
}

#[allow(clippy::too_many_arguments)]
fn run_topo(
    world: usize,
    ranks_per_node: usize,
    algo: AlgoSelect,
    axis: &Axis,
    steps: usize,
    calibrate_steps: usize,
    comm_chunk_bytes: Option<usize>,
    kernel: KernelConfig,
    grad_elim: bool,
    dtype: Dtype,
) -> DdpReport {
    train_ddp(
        || models::deep_mlp(3),
        || optim::by_name("adam").unwrap(),
        Hyper::default(),
        DdpConfig {
            world,
            schedule: axis.schedule,
            algo,
            ranks_per_node,
            planner_interconnect: None,
            calibrate_steps,
            planner_backward_s: None,
            steps,
            bucket_cap_bytes: axis.bucket_cap,
            comm_chunk_bytes,
            shard_stage: axis.stage,
            overlap_threads: axis.overlap,
            kernel,
            grad_elim,
            dtype,
            pipeline_stages: 1,
            micro_batches: 1,
            tensor_parallel: 1,
            load_from: None,
            save_to: None,
            local_batch_maker: Box::new(move |rank, step| {
                let mut rng = XorShiftRng::new(((rank as u64) << 32) | step as u64);
                image_batch(4, 3, 16, 16, 10, &mut rng)
            }),
        },
    )
}

fn main() {
    common::header(
        "§C.5 — DDP with schedule-integrated, topology-aware collectives",
        "reduce fused into the schedules; ZeRO-1 sharded fused updates; flat/ring/tree \
         algorithms; measured overlap; memsim-predicted wire accounting",
    );
    let smoke = common::smoke_mode();
    if smoke {
        println!("  (smoke mode: reduced worlds/steps for CI)");
    }

    let axes = [
        Axis {
            label: "base/scattered",
            schedule: ScheduleKind::Baseline,
            bucket_cap: None,
            stage: ShardStage::None,
            overlap: 0,
        },
        Axis {
            label: "bf/scattered",
            schedule: ScheduleKind::BackwardFusion,
            bucket_cap: None,
            stage: ShardStage::None,
            overlap: 0,
        },
        Axis {
            label: "base/bucketed",
            schedule: ScheduleKind::Baseline,
            bucket_cap: Some(CAP),
            stage: ShardStage::None,
            overlap: 0,
        },
        Axis {
            label: "bf/bucketed",
            schedule: ScheduleKind::BackwardFusion,
            bucket_cap: Some(CAP),
            stage: ShardStage::None,
            overlap: 0,
        },
        Axis {
            label: "bf/bkt+overlap",
            schedule: ScheduleKind::BackwardFusion,
            bucket_cap: Some(CAP),
            stage: ShardStage::None,
            overlap: 2,
        },
        Axis {
            label: "base/bkt+shard",
            schedule: ScheduleKind::Baseline,
            bucket_cap: Some(CAP),
            stage: ShardStage::Zero1,
            overlap: 0,
        },
        Axis {
            label: "bf/bkt+shard+ov",
            schedule: ScheduleKind::BackwardFusion,
            bucket_cap: Some(CAP),
            stage: ShardStage::Zero1,
            overlap: 2,
        },
    ];

    let steps = if smoke { 2 } else { 3 };
    let worlds: &[usize] = if smoke { &[1, 2] } else { &[1, 2, 4] };
    println!(
        "\n  world  axis              iter ms   comm MiB  rounds/st  overlap%  state KiB  loss"
    );
    for &world in worlds {
        let mut state_unsharded = None;
        let mut state_sharded = None;
        for axis in &axes {
            let r = run(world, CommAlgo::Flat.into(), axis, steps);
            println!(
                "  {world:>5}  {:<16} {:>8.2}  {:>9.2}  {:>9.1}  {:>7.0}%  {:>9.1}  {:.4}",
                axis.label,
                r.iter_ms,
                r.comm_bytes as f64 / (1 << 20) as f64,
                r.reduces_per_step,
                r.overlap_frac * 100.0,
                r.opt_state_bytes as f64 / 1024.0,
                r.losses.last().unwrap_or(&f32::NAN)
            );
            if axis.label == "base/bucketed" {
                state_unsharded = Some(r.opt_state_bytes);
            }
            if axis.label == "base/bkt+shard" {
                state_sharded = Some(r.opt_state_bytes);
            }
        }
        // perf-shape sanity: sharding cuts the per-replica optimizer
        // state by ~world (exact up to shard-balance rounding)
        let (u, s) = (state_unsharded.unwrap(), state_sharded.unwrap());
        assert!(
            s <= u / world as u64 + 1024,
            "world {world}: sharded state {s} B should be ~1/{world} of {u} B"
        );
        println!();
    }

    // ---- collective-algorithm axis: same math, different wire shape ----
    let algo_world = 2;
    let algo_axis = axes
        .iter()
        .find(|a| a.label == "bf/bkt+overlap")
        .expect("algo axis present");
    println!(
        "  algo axis (world={algo_world}, {}): measured vs predicted wire accounting",
        algo_axis.label
    );
    println!(
        "    algo   iter ms   comm MiB   hops/st   wait ms   overlap%   predicted MiB  hops"
    );
    let ic = machines::shared_mem(algo_world);
    // predicted per-step wire accounting over the *same* bucket layout:
    // derive unit element counts from the model itself
    let graph = models::deep_mlp(3);
    let lens: Vec<usize> = graph
        .store
        .params
        .iter()
        .map(|p| p.data.read().unwrap().value.len())
        .collect();
    let groups = optfuse::optim::bucket::partition_by_bytes(&lens, CAP);
    let mut flat_losses: Option<Vec<f32>> = None;
    let mut calib: Vec<machines::CommSample> = Vec::new();
    // (label, iter ms, comm MiB, wait ms, overlap) — reused by the
    // auto-vs-manual table below so the expensive runs happen once
    let mut manual_rows: Vec<(&'static str, f64, f64, f64, f64)> = Vec::new();
    for algo in CommAlgo::ALL {
        let r = run(algo_world, algo.into(), algo_axis, steps);
        calib.push(machines::CommSample {
            bytes: r.comm_bytes,
            hops: r.comm_hops,
            wait_s: r.comm_wait_ms / 1e3,
        });
        manual_rows.push((
            algo.label(),
            r.iter_ms,
            r.comm_bytes as f64 / (1 << 20) as f64,
            r.comm_wait_ms,
            r.overlap_frac,
        ));
        let mut predicted = WireCost::default();
        for group in &groups {
            let n: usize = group.iter().map(|i| lens[*i]).sum();
            predicted += ic.wire(algo, CollOp::AllReduce, n);
        }
        predicted += ic.wire(algo, CollOp::AllReduce, 1); // loss reduce
        println!(
            "    {:<5} {:>8.2}  {:>9.2}  {:>8.1}  {:>8.2}  {:>8.0}%  {:>12.2}  {}",
            algo.label(),
            r.iter_ms,
            r.comm_bytes as f64 / (1 << 20) as f64,
            r.comm_hops as f64 / steps as f64,
            r.comm_wait_ms,
            r.overlap_frac * 100.0,
            (predicted.bytes * steps as u64) as f64 / (1 << 20) as f64,
            predicted.hops * steps as u64
        );
        // the comm model's exact-accounting claim, live in the harness
        assert_eq!(
            r.comm_bytes,
            predicted.bytes * steps as u64,
            "{}: measured wire bytes must equal memsim's closed form",
            algo.label()
        );
        assert_eq!(
            r.comm_hops,
            predicted.hops * steps as u64,
            "{}: measured hop legs must equal memsim's closed form",
            algo.label()
        );
        match &flat_losses {
            None => flat_losses = Some(r.losses),
            Some(want) => {
                assert_eq!(want, &r.losses, "{}: algorithms must not change the math", algo.label())
            }
        }
    }
    println!();

    // ---- interconnect calibration: fit hop latency / link bandwidth
    // from the measured blocked time of the algo-axis runs (instead of
    // the hand-picked shared_mem constants). The four algorithms give
    // four (bytes, hops, wait) observations spanning hop-heavy (ring)
    // and volume-heavy (flat) mixes; a degenerate or non-physical fit
    // falls back to the preset, so this section never produces
    // nonsense. The fitted coefficients land in a per-run JSON artifact
    // (`bench-smoke/calibration.json`, uploaded by CI) and are compared
    // against the committed baseline — a >2× drift prints a
    // *non-blocking* GitHub warning annotation: the coefficients
    // describe runner contention as much as the code, so the trend is
    // tracked, not gated.
    let hand = machines::shared_mem(algo_world);
    let fitted = machines::fit_interconnect(algo_world, &calib);
    let fell_back = (fitted.intra_lat_s - hand.intra_lat_s).abs() < f64::EPSILON
        && (fitted.intra_bw - hand.intra_bw).abs() < f64::EPSILON;
    println!(
        "  shared_mem calibration (least squares over {} algo runs): \
         {:.2} µs/hop, {:.2} GB/s{}",
        calib.len(),
        fitted.intra_lat_s * 1e6,
        fitted.intra_bw / 1e9,
        if fell_back { "  [degenerate fit; hand-picked preset kept]" } else { "" }
    );
    assert!(fitted.intra_lat_s > 0.0 && fitted.intra_bw > 0.0, "calibrated preset is physical");
    // (the JSON artifact and the drift check move below the
    // self-calibrated sweep so they can carry the in-run probe fits too)
    println!();

    // ---- `--algo auto`: the planner's per-bucket mix, measured against
    // every manual global algorithm on the same axis — the auto-vs-
    // best-manual comparison of the acceptance criterion. The manual
    // rows are the algo-axis runs recorded above (not re-run); only the
    // auto session is new. Wallclock on a contended host is noisy, so
    // the hard assertions stay on math (auto bit-identical to the fixed
    // algorithms) and the comparison is reported for the artifact diff.
    let auto_axis = algo_axis;
    println!("  auto axis (world={algo_world}, {}): planned mix vs manual", auto_axis.label);
    println!("    algo   iter ms   comm MiB   wait ms   overlap%");
    let mut best_manual = f64::INFINITY;
    for (label, iter_ms, comm_mib, wait_ms, overlap) in &manual_rows {
        best_manual = best_manual.min(*iter_ms);
        println!(
            "    {:<5} {:>8.2}  {:>9.2}  {:>8.2}  {:>8.0}%",
            label,
            iter_ms,
            comm_mib,
            wait_ms,
            overlap * 100.0
        );
    }
    let auto = run(algo_world, AlgoSelect::Auto, auto_axis, steps);
    println!(
        "    {:<5} {:>8.2}  {:>9.2}  {:>8.2}  {:>8.0}%   (best manual {:.2} ms)",
        "auto",
        auto.iter_ms,
        auto.comm_bytes as f64 / (1 << 20) as f64,
        auto.comm_wait_ms,
        auto.overlap_frac * 100.0,
        best_manual
    );
    assert_eq!(
        flat_losses.as_ref().expect("algo axis ran"),
        &auto.losses,
        "auto must not change the math"
    );
    let plan = auto.plan.as_ref().expect("auto reports its plan");
    print!("{}", plan.table());

    // ---- self-calibrating `--algo auto` (the measure→fit→plan loop,
    // closed live): at topologies 2x2 and 1x4 the calibrated auto
    // session — probe steps, `fit_interconnect_on` over the measured
    // blocked time, re-plan with the measured backward window, atomic
    // mid-run routing swap — is measured min-of-3 against every uniform
    // algorithm × chunk-cap combination on the same axis. The hard
    // assertions stay on math (calibrated auto bit-identical to flat);
    // a calibrated run slower than the best uniform combo prints a
    // non-blocking `::warning::` (wallclock on a contended runner is
    // noise, the trend lands in the artifact diff).
    let reps = 3; // min-of-3 per the acceptance criterion
    fn min_of(reps: usize, f: &mut dyn FnMut() -> DdpReport) -> (DdpReport, f64) {
        let first = f();
        let mut best = first.iter_ms;
        for _ in 1..reps {
            best = best.min(f().iter_ms);
        }
        (first, best)
    }
    // (label, intra µs/hop, intra GB/s, inter µs/hop, inter GB/s)
    let mut probe_rows: Vec<(&'static str, f64, f64, f64, f64)> = Vec::new();
    let sweep_axis = algo_axis;
    for (topo_label, world, rpn) in [("2x2", 4usize, 2usize), ("1x4", 4, 0)] {
        println!(
            "  self-calibrated auto ({topo_label}, {}): min-of-{reps} vs uniform algo x chunk-cap",
            sweep_axis.label
        );
        println!("    combo          iter ms");
        let algos: &[CommAlgo] = if rpn > 0 { &CommAlgo::ALL } else { &CommAlgo::ONE_TIER };
        let mut best_manual = f64::INFINITY;
        let mut best_label = String::new();
        let mut flat_ref: Option<Vec<f32>> = None;
        for &algo in algos {
            for chunk in [None, Some(1usize << 16)] {
                let (r, ms) = min_of(reps, &mut || {
                    run_topo(
                        world,
                        rpn,
                        algo.into(),
                        sweep_axis,
                        steps,
                        0,
                        chunk,
                        KernelConfig::default(),
                        false,
                        Dtype::F32,
                    )
                });
                let label = format!(
                    "{}{}",
                    algo.label(),
                    if chunk.is_some() { "/chunk64K" } else { "" }
                );
                println!("    {label:<14} {ms:>7.2}");
                if ms < best_manual {
                    best_manual = ms;
                    best_label = label;
                }
                if algo == CommAlgo::Flat && chunk.is_none() {
                    flat_ref = Some(r.losses);
                }
            }
        }
        let (auto_r, auto_ms) = min_of(reps, &mut || {
            run_topo(
                world,
                rpn,
                AlgoSelect::Auto,
                sweep_axis,
                steps,
                2,
                None,
                KernelConfig::default(),
                false,
                Dtype::F32,
            )
        });
        println!("    {:<14} {auto_ms:>7.2}   (best uniform: {best_label} {best_manual:.2} ms)", "auto+calibrate");
        assert_eq!(
            flat_ref.as_ref().expect("flat combo ran"),
            &auto_r.losses,
            "{topo_label}: self-calibrated auto must not change the math"
        );
        let fit = auto_r.fitted.as_ref().expect("calibrated run reports its fit");
        probe_rows.push((
            topo_label,
            fit.intra_lat_s * 1e6,
            fit.intra_bw / 1e9,
            fit.inter_lat_s * 1e6,
            fit.inter_bw / 1e9,
        ));
        if auto_ms > best_manual {
            println!(
                "::warning title=calibrated auto slower than uniform::{topo_label}: \
                 auto+calibrate {auto_ms:.2} ms vs best uniform {best_label} {best_manual:.2} ms \
                 (min-of-{reps}; contended-runner wallclock, non-blocking)"
            );
        }
    }
    // fitted-vs-preset coefficient table: the probe fits next to the
    // hand-picked shared_mem preset they replace
    println!("\n  fitted vs preset coefficients (probe fits; preset = shared_mem)");
    println!("    topo   intra µs/hop  intra GB/s  inter µs/hop  inter GB/s");
    println!(
        "    {:<6} {:>12.2}  {:>10.2}  {:>12.2}  {:>10.2}",
        "preset",
        hand.intra_lat_s * 1e6,
        hand.intra_bw / 1e9,
        hand.intra_lat_s * 1e6,
        hand.intra_bw / 1e9
    );
    for (label, ius, ibw, xus, xbw) in &probe_rows {
        println!("    {label:<6} {ius:>12.2}  {ibw:>10.2}  {xus:>12.2}  {xbw:>10.2}");
    }

    // ---- calibration artifact (schema v2 extends optfuse-calibration-v1
    // with the in-run probe fits) + drift check vs the committed baseline
    let mut probes_json = String::new();
    for (i, (label, ius, ibw, xus, xbw)) in probe_rows.iter().enumerate() {
        probes_json.push_str(&format!(
            "    {{ \"topology\": \"{label}\", \"intra_hop_latency_us\": {ius:.6}, \
             \"intra_link_bw_gbps\": {ibw:.6}, \"inter_hop_latency_us\": {xus:.6}, \
             \"inter_link_bw_gbps\": {xbw:.6} }}{}\n",
            if i + 1 < probe_rows.len() { "," } else { "" }
        ));
    }
    let calib_json = format!(
        "{{\n  \"schema\": \"optfuse-calibration-v2\",\n  \"world\": {},\n  \
         \"hop_latency_us\": {:.6},\n  \"link_bw_gbps\": {:.6},\n  \"fell_back\": {},\n  \
         \"probes\": [\n{}  ]\n}}\n",
        algo_world,
        fitted.intra_lat_s * 1e6,
        fitted.intra_bw / 1e9,
        fell_back,
        probes_json
    );
    let _ = std::fs::create_dir_all("bench-smoke");
    if let Err(e) = std::fs::write("bench-smoke/calibration.json", &calib_json) {
        println!("  (calibration artifact not written: {e})");
    }
    // drift check vs the committed baseline (benches/calibration_baseline.json)
    let parse_field = |src: &str, key: &str| -> Option<f64> {
        // match the quoted `"key":` form only — key names also appear in
        // prose inside the baseline's "comment" field
        let needle = format!("\"{key}\":");
        let at = src.find(&needle)?;
        let rest = &src[at + needle.len()..];
        rest.trim_start()
            .split(|c: char| c == ',' || c == '\n' || c == '}')
            .next()?
            .trim()
            .parse()
            .ok()
    };
    match std::fs::read_to_string("benches/calibration_baseline.json") {
        Ok(base) => {
            // the probe drift keys track the 1x4 (flat-probe) fit — the
            // same shared-memory medium the baseline preset describes
            let probe = probe_rows.iter().find(|r| r.0 == "1x4");
            let mut checks = vec![
                ("hop_latency_us", fitted.intra_lat_s * 1e6),
                ("link_bw_gbps", fitted.intra_bw / 1e9),
            ];
            if let Some((_, ius, ibw, _, _)) = probe {
                checks.push(("probe_hop_latency_us", *ius));
                checks.push(("probe_link_bw_gbps", *ibw));
            }
            for (key, now) in checks {
                let Some(was) = parse_field(&base, key) else {
                    println!("  (calibration baseline missing '{key}'; skipping drift check)");
                    continue;
                };
                let ratio = if was > 0.0 { (now / was).max(was / now) } else { f64::INFINITY };
                if ratio > 2.0 {
                    // `::warning::` renders as a non-blocking GitHub
                    // annotation; locally it is just a printed line
                    println!(
                        "::warning title=shared_mem calibration drift::{key} drifted {ratio:.1}x \
                         vs committed baseline ({was:.3} -> {now:.3})"
                    );
                } else {
                    println!("  calibration trend: {key} {was:.3} -> {now:.3} ({ratio:.2}x)");
                }
            }
        }
        Err(e) => println!("  (no calibration baseline committed: {e})"),
    }
    println!();

    // ---- shard-stage axis: the per-stage peak-memory table, asserted
    // against memsim's closed form *exactly* (both sides sum rank 0's
    // shard spans over the same bucket layout) ----
    let stage_world = algo_world;
    let stage_units: Vec<usize> = groups
        .iter()
        .map(|group| group.iter().map(|i| lens[*i]).sum())
        .collect();
    println!(
        "  shard-stage axis (world={stage_world}, base/bucketed, adam): per-replica peak \
         arena bytes"
    );
    println!("    stage   grads KiB   values KiB   state KiB   comm MiB   loss");
    for stage in ShardStage::ALL {
        let axis = Axis {
            label: "stage",
            schedule: ScheduleKind::Baseline,
            bucket_cap: Some(CAP),
            stage,
            overlap: 0,
        };
        let r = run(stage_world, CommAlgo::Flat.into(), &axis, steps);
        let want = stage_memory(&stage_units, 2, stage, stage_world);
        assert_eq!(
            r.peak_grad_arena_bytes,
            want.grad_bytes,
            "{}: measured grad-arena peak must equal memsim's closed form",
            stage.label()
        );
        assert_eq!(
            r.peak_value_arena_bytes,
            want.value_bytes,
            "{}: measured value-arena peak must equal memsim's closed form",
            stage.label()
        );
        assert_eq!(
            r.opt_state_bytes,
            want.opt_state_bytes,
            "{}: measured optimizer-state bytes must equal memsim's closed form",
            stage.label()
        );
        println!(
            "    {:<6} {:>10.1}  {:>10.1}  {:>10.1}  {:>9.2}  {:.4}",
            stage.label(),
            r.peak_grad_arena_bytes as f64 / 1024.0,
            r.peak_value_arena_bytes as f64 / 1024.0,
            r.opt_state_bytes as f64 / 1024.0,
            r.comm_bytes as f64 / (1 << 20) as f64,
            r.losses.last().unwrap_or(&f32::NAN)
        );
    }
    println!();

    // ---- `--kernel` axis: the compute-kernel modes under DDP — one row
    // per mode on the overlapped backward-fusion axis. The math must be
    // bit-identical across modes (the kernel-equivalence contract); the
    // iteration times land in the uploaded artifact so per-mode DDP step
    // time is tracked per PR alongside the single-replica table in
    // bucket_locality.
    println!("  kernel axis (world={algo_world}, {}): compute-kernel modes", algo_axis.label);
    println!("    kernel    iter ms   comm MiB   overlap%   loss");
    let mut kernel_losses: Option<Vec<f32>> = None;
    for mode in KernelMode::ALL {
        let kernel = KernelConfig { mode, lanes: 8, threads: 2 };
        let r = run_kernel(algo_world, CommAlgo::Flat.into(), algo_axis, steps, kernel);
        println!(
            "    {:<8} {:>8.2}  {:>9.2}  {:>8.0}%   {:.4}",
            mode.label(),
            r.iter_ms,
            r.comm_bytes as f64 / (1 << 20) as f64,
            r.overlap_frac * 100.0,
            r.losses.last().unwrap_or(&f32::NAN)
        );
        match &kernel_losses {
            None => kernel_losses = Some(r.losses),
            Some(want) => assert_eq!(
                want,
                &r.losses,
                "{}: kernel modes must not change the math",
                mode.label()
            ),
        }
    }
    println!();

    // ---- precision axis: grad-elim × dtype on the overlapped
    // backward-fusion axis. FP32 `--grad-elim` is bit-identical to the
    // grad-arena path (the drain-point job consumes the same
    // contribution in place) while the measured grad-arena peak goes to
    // zero; `--dtype bf16` halves every collective's wire bytes
    // *exactly* (each closed-form byte term is a multiple of 4 per
    // element) while optimizer state stays FP32 master bytes. Every
    // measured row is asserted against the dtype/elimination-aware
    // memsim closed forms.
    println!("  precision axis (world={algo_world}, {}): grad-elim x dtype", algo_axis.label);
    println!(
        "    dtype  elim    iter ms   comm MiB   grads KiB   values KiB   state KiB   loss"
    );
    let mut predicted_flat = WireCost::default();
    for group in &groups {
        let n: usize = group.iter().map(|i| lens[*i]).sum();
        predicted_flat += ic.wire(CommAlgo::Flat, CollOp::AllReduce, n);
    }
    predicted_flat += ic.wire(CommAlgo::Flat, CollOp::AllReduce, 1); // loss reduce
    let mut precision_rows: Vec<DdpReport> = Vec::new();
    for dtype in [Dtype::F32, Dtype::Bf16] {
        for grad_elim in [false, true] {
            let r = run_precision(
                algo_world,
                CommAlgo::Flat.into(),
                algo_axis,
                steps,
                grad_elim,
                dtype,
            );
            println!(
                "    {:<5}  {:<5} {:>9.2}  {:>9.2}  {:>9.1}  {:>10.1}  {:>9.1}  {:.4}",
                dtype.label(),
                grad_elim,
                r.iter_ms,
                r.comm_bytes as f64 / (1 << 20) as f64,
                r.peak_grad_arena_bytes as f64 / 1024.0,
                r.peak_value_arena_bytes as f64 / 1024.0,
                r.opt_state_bytes as f64 / 1024.0,
                r.losses.last().unwrap_or(&f32::NAN)
            );
            let label = format!("{} elim={grad_elim}", dtype.label());
            // arenas: the dtype/elimination-aware closed form, exactly
            // (elimination is effective here: backward-fusion + bucketed)
            let want =
                stage_memory_opts(&stage_units, 2, ShardStage::None, algo_world, grad_elim, dtype);
            assert_eq!(r.peak_grad_arena_bytes, want.grad_bytes, "{label}: grad-arena peak");
            assert_eq!(r.peak_value_arena_bytes, want.value_bytes, "{label}: value-arena peak");
            assert_eq!(r.opt_state_bytes, want.opt_state_bytes, "{label}: fp32 master state");
            // wire: the dtype-aware closed form, exactly
            let predicted = predicted_flat.scaled_to(dtype.elem_bytes());
            assert_eq!(
                r.comm_bytes,
                predicted.bytes * steps as u64,
                "{label}: measured wire bytes must equal the dtype-aware closed form"
            );
            assert_eq!(r.comm_hops, predicted.hops * steps as u64, "{label}: hop legs");
            precision_rows.push(r);
        }
    }
    // rows land in (f32,keep) (f32,elim) (bf16,keep) (bf16,elim) order
    let (f32_keep, f32_elim, bf16_keep, bf16_elim) =
        (&precision_rows[0], &precision_rows[1], &precision_rows[2], &precision_rows[3]);
    assert_eq!(
        flat_losses.as_ref().expect("algo axis ran"),
        &f32_keep.losses,
        "precision axis: f32 baseline row must bit-match the algo-axis flat run"
    );
    assert_eq!(f32_keep.losses, f32_elim.losses, "f32: grad-elim must not change the math");
    assert_eq!(bf16_keep.losses, bf16_elim.losses, "bf16: grad-elim must not change the math");
    assert_eq!(f32_keep.comm_bytes, 2 * bf16_keep.comm_bytes, "bf16 wire bytes exactly half");
    assert_eq!(f32_keep.comm_hops, bf16_keep.comm_hops, "hop count is dtype-independent");
    assert_eq!(f32_elim.comm_bytes, f32_keep.comm_bytes, "grad-elim must not change traffic");
    assert_eq!(bf16_elim.comm_bytes, bf16_keep.comm_bytes, "grad-elim must not change traffic");
    println!();

    // ---- bf16 convergence table: per-model final-loss gap vs the fp32
    // reference, written to bench-smoke/bf16_convergence.txt so CI
    // uploads it next to kernel_modes.txt. A gap beyond the committed
    // tolerance (`bf16_loss_gap_rel` in benches/calibration_baseline.json)
    // prints a *non-blocking* `::warning::` — mixed-precision convergence
    // is a tracked trend here; the hard gates live in
    // rust/tests/precision_matrix.rs.
    let conv_steps = if smoke { 4 } else { 8 };
    let conv_models: &[(&str, fn() -> Graph)] =
        &[("deep_mlp", || models::deep_mlp(3)), ("mlp", || models::mlp(99))];
    let tol = std::fs::read_to_string("benches/calibration_baseline.json")
        .ok()
        .and_then(|base| parse_field(&base, "bf16_loss_gap_rel"))
        .unwrap_or(0.15);
    println!("  bf16 convergence (world=1, bf/bucketed, {conv_steps} steps, tolerance {tol}):");
    println!("    model       f32 loss   bf16 loss   rel gap");
    let mut conv_table = format!(
        "bf16 convergence vs fp32 (world=1, backward-fusion, bucketed, {conv_steps} steps, \
         tolerance {tol})\nmodel       f32 loss   bf16 loss   rel gap\n"
    );
    for (name, make) in conv_models {
        let run_dtype = |dtype: Dtype| {
            let mut cfg = DdpConfig::new(
                1,
                ScheduleKind::BackwardFusion,
                conv_steps,
                Box::new(move |rank, step| {
                    let mut rng = XorShiftRng::new(((rank as u64) << 32) | step as u64);
                    image_batch(4, 3, 16, 16, 10, &mut rng)
                }),
            );
            cfg.bucket_cap_bytes = Some(CAP);
            cfg.overlap_threads = 2;
            cfg.grad_elim = false;
            cfg.dtype = dtype;
            train_ddp(*make, || optim::by_name("adam").unwrap(), Hyper::default(), cfg)
        };
        let f = *run_dtype(Dtype::F32).losses.last().expect("f32 run produced losses");
        let b = *run_dtype(Dtype::Bf16).losses.last().expect("bf16 run produced losses");
        assert!(b.is_finite(), "{name}: bf16 training must stay finite");
        let gap = (f - b).abs() as f64 / f.abs().max(1e-6) as f64;
        let row = format!("{name:<10} {f:>9.4}  {b:>10.4}  {gap:>8.4}\n");
        print!("    {row}");
        conv_table.push_str(&row);
        if gap > tol {
            println!(
                "::warning title=bf16 convergence gap::{name}: relative final-loss gap \
                 {gap:.4} exceeds tolerance {tol} (non-blocking; trend lands in the artifact)"
            );
        }
    }
    if let Err(e) = std::fs::write("bench-smoke/bf16_convergence.txt", &conv_table) {
        println!("  (bf16 convergence artifact not written: {e})");
    }
    println!();

    // ---- DP×PP axis: 1F1B pipeline grids over the p2p mailbox. Each
    // row runs an S-stage × dp-chain grid with M micro-batches and
    // compares the measured worst-stage bubble against the balanced
    // closed form `(S−1)/(M+S−1)` (`memsim::pipeline_bubble_fracs`);
    // the math is asserted bit-identical to the single-stage run with
    // the same micro-batched accumulation, and the activation p2p leg
    // is asserted to equal `memsim::pipeline_act_bytes` exactly. Rows
    // land in bench-smoke/pipeline_bubbles.txt so the bubble trend is
    // tracked per PR next to the convergence table (wallclock bubbles
    // on a contended runner are noise, so the fraction columns are a
    // reported trend, not a gate).
    let pipe_grids: &[(usize, u64, usize)] =
        if smoke { &[(2, 2, 1), (3, 4, 1)] } else { &[(2, 1, 1), (2, 2, 2), (2, 4, 1), (3, 4, 1)] };
    let run_pipe = |stages: usize, micro: u64, dp: usize, algo: AlgoSelect| {
        let mut cfg = DdpConfig::new(
            dp,
            ScheduleKind::BackwardFusion,
            steps,
            Box::new(move |rank, step| {
                let mut rng = XorShiftRng::new(((rank as u64) << 32) | step as u64);
                image_batch(4, 3, 16, 16, 10, &mut rng)
            }),
        );
        cfg.pipeline_stages = stages;
        cfg.micro_batches = micro;
        cfg.bucket_cap_bytes = Some(CAP);
        cfg.grad_elim = false;
        cfg.dtype = Dtype::F32;
        cfg.algo = algo;
        train_ddp(|| models::deep_mlp(3), || optim::by_name("adam").unwrap(), Hyper::default(), cfg)
    };
    println!("  DP×PP axis (deep_mlp, bf/bucketed): 1F1B grids, measured vs predicted bubble");
    println!("    S  M  dp   iter ms   act KiB   msgs   pred worst%   meas worst%");
    let mut pipe_table = String::from(
        "1F1B pipeline bubbles (deep_mlp, backward-fusion, bucketed)\n\
         predicted = balanced closed form (S-1)/(M+S-1); measured = worst per-stage\n\
         activation-blocked share on chain 0 (contended-runner wallclock: trend, not gate)\n\
         S  M  dp   act KiB   msgs   predicted   measured\n",
    );
    for &(stages, micro, dp) in pipe_grids {
        let reference = run_pipe(1, micro, dp, CommAlgo::Flat.into());
        let r = run_pipe(stages, micro, dp, CommAlgo::Flat.into());
        assert_eq!(
            reference.losses, r.losses,
            "S={stages} M={micro} dp={dp}: pipelining must not change the math"
        );
        // exact activation accounting against the memsim closed form,
        // boundary shapes taken from the graph's own cut choice
        let g = models::deep_mlp(3);
        let ext_shapes: Vec<Vec<usize>> = vec![vec![4, 3, 16, 16], vec![4]];
        let cuts = g.pipeline_cuts(stages, &ext_shapes);
        let micro_ext: Vec<Vec<usize>> = ext_shapes
            .iter()
            .map(|sh| {
                let mut sh = sh.clone();
                sh[0] /= micro as usize;
                sh
            })
            .collect();
        let node_shapes = g.infer_shapes(&micro_ext);
        let boundary: Vec<usize> = cuts.iter().map(|&c| node_shapes[c].iter().product()).collect();
        let want_bytes =
            optfuse::memsim::pipeline_act_bytes(&boundary, micro as usize, dp) * steps as u64;
        assert_eq!(
            r.act_bytes, want_bytes,
            "S={stages} M={micro} dp={dp}: activation bytes must equal memsim's closed form"
        );
        let balanced = vec![1.0f64; stages];
        let predicted = optfuse::memsim::pipeline_bubble_fracs(&balanced, micro as usize)
            .iter()
            .fold(0.0f64, |a, &b| a.max(b));
        let measured = r.bubble_frac.iter().fold(0.0f64, |a, &b| a.max(b));
        println!(
            "    {stages}  {micro}  {dp:>2}  {:>8.2}  {:>8.1}  {:>5}  {:>10.1}%  {:>10.1}%",
            r.iter_ms,
            r.act_bytes as f64 / 1024.0,
            r.act_msgs,
            predicted * 100.0,
            measured * 100.0
        );
        pipe_table.push_str(&format!(
            "{stages}  {micro}  {dp:>2}  {:>8.1}  {:>5}  {:>9.1}%  {:>8.1}%\n",
            r.act_bytes as f64 / 1024.0,
            r.act_msgs,
            predicted * 100.0,
            measured * 100.0
        ));
    }
    // `--algo auto` composes with the pipeline axis: per-stage plans,
    // same math, iteration time reported next to flat for the trend
    let pipe_flat = run_pipe(2, 2, 2, CommAlgo::Flat.into());
    let pipe_auto = run_pipe(2, 2, 2, AlgoSelect::Auto);
    assert_eq!(
        pipe_flat.losses, pipe_auto.losses,
        "pipelined auto must not change the math"
    );
    assert!(pipe_auto.plan.is_some(), "pipelined auto reports stage 0's plan");
    println!(
        "    auto vs flat at S=2 M=2 dp=2: {:.2} ms vs {:.2} ms (math bit-identical)",
        pipe_auto.iter_ms, pipe_flat.iter_ms
    );
    pipe_table.push_str(&format!(
        "auto S=2 M=2 dp=2: {:.2} ms vs flat {:.2} ms\n",
        pipe_auto.iter_ms, pipe_flat.iter_ms
    ));
    if let Err(e) = std::fs::write("bench-smoke/pipeline_bubbles.txt", &pipe_table) {
        println!("  (pipeline bubble artifact not written: {e})");
    }
    println!();

    // ---- 3D DP×PP×TP axis: Megatron column/row splits over the p2p
    // mailbox, composed with pipeline stages and DP chains. The probe
    // model is a column/row pair stack with the hidden waist at exactly
    // T, so every rank's shard is one column wide and the rank-ordered
    // fold reproduces the unsplit matmul's accumulation order — each
    // grid row is asserted bit-identical to the T=1 run of the same
    // model, and the S=1 rows assert the tp fold leg against
    // `memsim::tp_act_bytes` / `tp_act_msgs` exactly (fold elements
    // derived from the graph's own `tp_partition` sync points). Rows
    // land in bench-smoke/tp_scaling.txt so the fold-traffic trend is
    // tracked per PR next to the bubble table.
    fn tp_pairs_model(hidden: usize) -> Graph {
        let mut rng = XorShiftRng::new(77);
        let mut g = Graph::new("tp-pairs", 2);
        let mut prev = Src::External(0);
        for l in 0..3 {
            let w1 = g.param(&format!("pair{l}.col.w"), &[16, hidden], &mut rng);
            let col = g.push(
                &format!("pair{l}.col"),
                Box::new(Linear::new(false)),
                vec![prev],
                vec![w1],
            );
            let act =
                g.push(&format!("pair{l}.relu"), Box::new(Relu), vec![Src::Node(col)], vec![]);
            let w2 = g.param(&format!("pair{l}.row.w"), &[hidden, 16], &mut rng);
            let row = g.push(
                &format!("pair{l}.row"),
                Box::new(Linear::new(false)),
                vec![Src::Node(act)],
                vec![w2],
            );
            prev = Src::Node(row);
        }
        let loss = g.push("mse", Box::new(MseLoss), vec![prev, Src::External(1)], vec![]);
        g.set_loss(loss);
        g
    }
    let run_tp = |t: usize, hidden: usize, stages: usize, micro: u64, dp: usize| {
        let mut cfg = DdpConfig::new(
            dp,
            ScheduleKind::BackwardFusion,
            steps,
            Box::new(move |rank, step| {
                let mut rng = XorShiftRng::new(31_000 + ((rank as u64) << 20) + step as u64);
                vec![Tensor::randn(&[4, 16], 1.0, &mut rng), Tensor::randn(&[4, 16], 1.0, &mut rng)]
            }),
        );
        cfg.tensor_parallel = t;
        cfg.pipeline_stages = stages;
        cfg.micro_batches = micro;
        cfg.grad_elim = false;
        cfg.dtype = Dtype::F32;
        train_ddp(
            move || tp_pairs_model(hidden),
            || optim::by_name("adam").unwrap(),
            Hyper::default(),
            cfg,
        )
    };
    let tp_grids: &[(usize, usize, u64, usize)] = if smoke {
        &[(2, 1, 1, 1), (2, 2, 2, 1)]
    } else {
        &[(2, 1, 1, 1), (4, 1, 1, 1), (2, 2, 2, 1), (2, 2, 2, 2), (4, 1, 2, 2)]
    };
    println!("  DP×PP×TP axis (pair-stack probe, hidden = T): measured vs closed-form fold leg");
    println!("    T  S  M  dp   iter ms   tp KiB   msgs   closed-form KiB");
    let mut tp_table = String::from(
        "3D DP×PP×TP fold traffic (column/row pair stack, hidden = T, backward-fusion)\n\
         bit-identity vs the T=1 run asserted per row; S=1 rows asserted equal to the\n\
         memsim::tp_act_bytes / tp_act_msgs closed forms (exact f32 wire, per fold, per\n\
         micro-batch, per DP chain)\n\
         T  S  M  dp   tp KiB   msgs   closed-form KiB\n",
    );
    for &(t, stages, micro, dp) in tp_grids {
        let reference = run_tp(1, t, stages, micro, dp);
        let r = run_tp(t, t, stages, micro, dp);
        assert_eq!(
            reference.losses, r.losses,
            "T={t} S={stages} M={micro} dp={dp}: tensor parallelism must not change the math"
        );
        assert!(r.tp_bytes > 0, "T={t}: fold traffic recorded");
        // S=1: fold elements from the graph's own partition sync points
        let closed_bytes = if stages == 1 {
            let (pg, info) = tp_pairs_model(t).tp_partition(t, 0, None);
            let micro_ext = vec![vec![4 / micro as usize, 16], vec![4 / micro as usize, 16]];
            let shapes = pg.infer_shapes(&micro_ext);
            let mut sync_elems: Vec<usize> = Vec::new();
            for &(row, _) in &info.fwd_sync {
                sync_elems.push(shapes[row].iter().product());
            }
            for &col in &info.bwd_sync {
                sync_elems.push(match pg.nodes[col].inputs[0] {
                    Src::Node(p) => shapes[p].iter().product(),
                    Src::External(e) => micro_ext[e].iter().product(),
                });
            }
            let want_bytes =
                optfuse::memsim::tp_act_bytes(&sync_elems, t, micro as usize, dp) * steps as u64;
            let want_msgs =
                optfuse::memsim::tp_act_msgs(sync_elems.len(), t, micro as usize, dp)
                    * steps as u64;
            assert_eq!(
                r.tp_bytes, want_bytes,
                "T={t} M={micro} dp={dp}: fold bytes must equal memsim's closed form"
            );
            assert_eq!(
                r.tp_msgs, want_msgs,
                "T={t} M={micro} dp={dp}: fold messages must equal memsim's closed form"
            );
            want_bytes
        } else {
            0 // S>1 cut placement owns the split; exactness pinned in tests
        };
        println!(
            "    {t}  {stages}  {micro}  {dp:>2}  {:>8.2}  {:>7.1}  {:>5}  {:>15.1}",
            r.iter_ms,
            r.tp_bytes as f64 / 1024.0,
            r.tp_msgs,
            closed_bytes as f64 / 1024.0
        );
        tp_table.push_str(&format!(
            "{t}  {stages}  {micro}  {dp:>2}  {:>7.1}  {:>5}  {:>15.1}\n",
            r.tp_bytes as f64 / 1024.0,
            r.tp_msgs,
            closed_bytes as f64 / 1024.0
        ));
    }
    if let Err(e) = std::fs::write("bench-smoke/tp_scaling.txt", &tp_table) {
        println!("  (tp scaling artifact not written: {e})");
    }
    println!();

    // comm volume grows with world size (per-rank copies per collective);
    // reuse the sweep's largest world in smoke mode so the CI job never
    // runs a configuration bigger than the reduced sweep itself
    let top_world = *worlds.last().unwrap();
    let comm1 = run(1, CommAlgo::Flat.into(), &axes[0], 1).comm_bytes;
    let comm_top = run(top_world, CommAlgo::Flat.into(), &axes[0], 1).comm_bytes;
    assert!(
        comm_top > (top_world as u64 - 1) * comm1,
        "all-reduce traffic grows with world size"
    );
    println!(
        "  traffic scales with world ✓ · sharded state ~1/W ✓ · algo wire accounting exact ✓\n\
         \x20 (single-core host: wallclock scaling is contended; traffic/rounds/hops/footprint\n\
         \x20 accounting is exact)\n\
         §C.5 reproduced ✓ — math equivalence asserted in rust/tests/integration_ddp.rs and\n\
         rust/tests/integration_comm_model.rs"
    );
}
