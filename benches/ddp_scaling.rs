//! §C.5: distributed data parallel — "the training speedup with DDP is
//! similar to that on a single GPU". The harness sweeps the new comm
//! axes: schedule (baseline vs backward-fusion), storage (scattered vs
//! bucketed collectives), ZeRO-1 sharded updates on/off, and
//! backward-fusion overlap threads on/off — reporting iteration time,
//! communicator traffic, rounds per step, the measured comm/compute
//! overlap fraction, and the per-replica optimizer-state footprint.
//!
//! The math-equivalence assertions that used to live here (schedules
//! agree at every world size; world=W bit-equal to a single process;
//! sharded ⇄ unsharded bit-equal) moved to
//! `rust/tests/integration_ddp.rs`, where `cargo test` actually runs
//! them in CI; this harness keeps only perf-shaped sanity checks.

#[path = "common.rs"]
mod common;

use optfuse::data::image_batch;
use optfuse::ddp::{train_ddp, DdpConfig, DdpReport};
use optfuse::graph::ScheduleKind;
use optfuse::models;
use optfuse::optim::{self, Hyper};
use optfuse::util::XorShiftRng;

struct Axis {
    label: &'static str,
    schedule: ScheduleKind,
    bucket_cap: Option<usize>,
    shard: bool,
    overlap: usize,
}

const CAP: usize = 1 << 20;

fn run(world: usize, axis: &Axis, steps: usize) -> DdpReport {
    train_ddp(
        || models::deep_mlp(3),
        || optim::by_name("adam").unwrap(),
        Hyper::default(),
        DdpConfig {
            world,
            schedule: axis.schedule,
            steps,
            bucket_cap_bytes: axis.bucket_cap,
            shard_updates: axis.shard,
            overlap_threads: axis.overlap,
            load_from: None,
            save_to: None,
            local_batch_maker: Box::new(move |rank, step| {
                let mut rng = XorShiftRng::new(((rank as u64) << 32) | step as u64);
                image_batch(4, 3, 16, 16, 10, &mut rng)
            }),
        },
    )
}

fn main() {
    common::header(
        "§C.5 — DDP with schedule-integrated collectives",
        "reduce fused into the schedules; ZeRO-1 sharded fused updates; measured overlap",
    );

    let axes = [
        Axis {
            label: "base/scattered",
            schedule: ScheduleKind::Baseline,
            bucket_cap: None,
            shard: false,
            overlap: 0,
        },
        Axis {
            label: "bf/scattered",
            schedule: ScheduleKind::BackwardFusion,
            bucket_cap: None,
            shard: false,
            overlap: 0,
        },
        Axis {
            label: "base/bucketed",
            schedule: ScheduleKind::Baseline,
            bucket_cap: Some(CAP),
            shard: false,
            overlap: 0,
        },
        Axis {
            label: "bf/bucketed",
            schedule: ScheduleKind::BackwardFusion,
            bucket_cap: Some(CAP),
            shard: false,
            overlap: 0,
        },
        Axis {
            label: "bf/bkt+overlap",
            schedule: ScheduleKind::BackwardFusion,
            bucket_cap: Some(CAP),
            shard: false,
            overlap: 2,
        },
        Axis {
            label: "base/bkt+shard",
            schedule: ScheduleKind::Baseline,
            bucket_cap: Some(CAP),
            shard: true,
            overlap: 0,
        },
        Axis {
            label: "bf/bkt+shard+ov",
            schedule: ScheduleKind::BackwardFusion,
            bucket_cap: Some(CAP),
            shard: true,
            overlap: 2,
        },
    ];

    let steps = 3;
    println!(
        "\n  world  axis              iter ms   comm MiB  rounds/st  overlap%  state KiB  loss"
    );
    for world in [1usize, 2, 4] {
        let mut state_unsharded = None;
        let mut state_sharded = None;
        for axis in &axes {
            let r = run(world, axis, steps);
            println!(
                "  {world:>5}  {:<16} {:>8.2}  {:>9.2}  {:>9.1}  {:>7.0}%  {:>9.1}  {:.4}",
                axis.label,
                r.iter_ms,
                r.comm_bytes as f64 / (1 << 20) as f64,
                r.reduces_per_step,
                r.overlap_frac * 100.0,
                r.opt_state_bytes as f64 / 1024.0,
                r.losses.last().unwrap_or(&f32::NAN)
            );
            if axis.label == "base/bucketed" {
                state_unsharded = Some(r.opt_state_bytes);
            }
            if axis.label == "base/bkt+shard" {
                state_sharded = Some(r.opt_state_bytes);
            }
        }
        // perf-shape sanity: sharding cuts the per-replica optimizer
        // state by ~world (exact up to shard-balance rounding)
        let (u, s) = (state_unsharded.unwrap(), state_sharded.unwrap());
        assert!(
            s <= u / world as u64 + 1024,
            "world {world}: sharded state {s} B should be ~1/{world} of {u} B"
        );
        println!();
    }

    // comm volume grows with world size (per-rank copies per collective)
    let comm1 = run(1, &axes[0], 1).comm_bytes;
    let comm4 = run(4, &axes[0], 1).comm_bytes;
    assert!(comm4 > 3 * comm1, "all-reduce traffic grows with world size");
    println!(
        "  traffic scales with world ✓ · sharded state ~1/W ✓ (single-core host: wallclock\n\
         \x20 scaling is contended; traffic/rounds/footprint accounting is exact)\n\
         §C.5 reproduced ✓ — math equivalence asserted in rust/tests/integration_ddp.rs"
    );
}
