//! Table 2: MobileNetV2 bs=32 across the paper's three machines.
//!
//! Paper rows (runtime ms | FF speedup | BF speedup):
//!   TITAN Xp + i9-7900X:      98.77 | 1.17 | 1.19
//!   GTX 1080 + i7-3770:      163.60 | 1.12 | 1.26
//!   GTX 1070maxQ + i7-8750H: 174.43 | 1.11 | 1.10

#[path = "common.rs"]
mod common;

use optfuse::memsim::{machines, spec::OptSpec, zoo};

struct PaperRow {
    machine: &'static str,
    baseline_ms: f64,
    ff: f64,
    bf: f64,
}

const PAPER: [PaperRow; 3] = [
    PaperRow { machine: "TITAN Xp + i9-7900X", baseline_ms: 98.77, ff: 1.17, bf: 1.19 },
    PaperRow { machine: "GTX 1080 + i7-3770", baseline_ms: 163.60, ff: 1.12, bf: 1.26 },
    PaperRow { machine: "GTX 1070 maxQ + i7-8750H", baseline_ms: 174.43, ff: 1.11, bf: 1.10 },
];

fn main() {
    common::header(
        "Table 2 — MobileNetV2 bs=32 across machines",
        "speedups 1.10–1.26 on all three testbeds; slower testbeds run slower in absolute terms",
    );

    let net = zoo::mobilenet_v2();
    let opt = OptSpec::adam();
    println!(
        "\n  {:<26} {:>12} {:>8} {:>8}   | paper: {:>8} {:>6} {:>6}",
        "machine", "baseline ms", "FF", "BF", "ms", "FF", "BF"
    );
    let mut base_ms = Vec::new();
    for (m, p) in machines::table2_machines().iter().zip(PAPER.iter()) {
        let (base_s, ff, bf) = common::sim_speedups(m, &net, &opt, 32);
        println!(
            "  {:<26} {:>12.2} {:>8.3} {:>8.3}   | {:>8.2} {:>6.2} {:>6.2}",
            m.name,
            base_s * 1e3,
            ff,
            bf,
            p.baseline_ms,
            p.ff,
            p.bf
        );
        base_ms.push(base_s * 1e3);
        // shape assertions: speedups land in the paper's band
        assert!(ff > 1.05 && ff < 1.40, "{}: FF {ff:.3} out of band", m.name);
        assert!(bf > 1.05 && bf < 1.45, "{}: BF {bf:.3} out of band", m.name);
    }
    // absolute runtime ordering matches the paper (titan fastest, 1070 slowest)
    assert!(base_ms[0] < base_ms[1] && base_ms[1] < base_ms[2], "machine ordering");
    println!(
        "\n  ordering holds: TITAN Xp < GTX 1080 < GTX 1070maxQ baseline runtimes ✓\n\
         Table 2 reproduced (shape: who wins, rough factors, ordering) ✓"
    );
}
