//! Table 1: locality / parallelism / global-information matrix, made
//! quantitative: graph dependency depth (3n vs 2n+1), cache-hit bytes per
//! schedule (locality), hidden optimizer seconds (parallelism), and the
//! global-info compatibility check.

#[path = "common.rs"]
mod common;

use optfuse::exec::{ExecConfig, Executor};
use optfuse::graph::ScheduleKind;
use optfuse::memsim::{self, machines, spec::OptSpec, zoo};
use optfuse::models;
use optfuse::optim::{GlobalNormClip, Hyper, Sgd};

fn main() {
    common::header(
        "Table 1 — method properties (quantified)",
        "baseline: no locality/parallelism, global ok; FF: +locality, global ok; BF: +locality+parallelism, no global",
    );

    // --- dependency depth: 3n vs 2n+1 (paper §3) ---
    println!("\ngraph dependency depth (n = parameterized layers):");
    for (name, build) in [
        ("mobilenet_v2_ish", models::mobilenet_v2_ish as fn(u64) -> optfuse::graph::Graph),
        ("resnet_ish", models::resnet_ish),
        ("deep_mlp", models::deep_mlp),
    ] {
        let g = build(1);
        let n = g.num_layers();
        println!(
            "  {name:<18} n={n:<3}  baseline {:<4} forward-fusion {:<4} backward-fusion {:<4} (= 2n+1)",
            g.schedule_depth(ScheduleKind::Baseline),
            g.schedule_depth(ScheduleKind::ForwardFusion),
            g.schedule_depth(ScheduleKind::BackwardFusion),
        );
        assert_eq!(g.schedule_depth(ScheduleKind::BackwardFusion), 2 * n + 1);
    }

    // --- locality: cache-hit bytes per schedule (memsim replay) ---
    println!("\nsimulated cache-hit bytes per iteration (MobileNetV2 @ TITAN Xp, bs=32, adam):");
    let m = machines::titan_xp();
    let net = zoo::mobilenet_v2();
    let opt = OptSpec::adam();
    let mut base_dram = 0;
    for kind in ScheduleKind::ALL {
        let r = memsim::simulate(&m, &net, &opt, 32, kind);
        if kind == ScheduleKind::Baseline {
            base_dram = r.dram_bytes;
        }
        println!(
            "  {:<16} dram {:>8.2} MiB  (saved {:>7.2} MiB)  opt-hidden {:>6.2} ms",
            kind.label(),
            r.dram_bytes as f64 / (1 << 20) as f64,
            (base_dram as i64 - r.dram_bytes as i64) as f64 / (1 << 20) as f64,
            r.opt_hidden_s * 1e3,
        );
        if kind != ScheduleKind::Baseline {
            // locality = less DRAM traffic than the separated-stage baseline
            assert!(r.dram_bytes < base_dram, "fusion must reduce DRAM traffic");
        }
        if kind == ScheduleKind::BackwardFusion {
            assert!(r.opt_hidden_s > 0.0, "BF must add parallelism");
        }
    }

    // --- global information (paper Table 1 last column) ---
    println!("\nglobal-information optimizer (global-norm clip):");
    for kind in ScheduleKind::ALL {
        let r = Executor::new(
            models::mlp(1),
            Box::new(GlobalNormClip { inner: Sgd, max_norm: 1.0 }),
            Hyper::default(),
            ExecConfig { schedule: kind, ..Default::default() },
        );
        println!(
            "  {:<16} {}",
            kind.label(),
            if r.is_ok() { "supported ✓" } else { "rejected (needs global info) ✗" }
        );
        match kind {
            ScheduleKind::BackwardFusion => assert!(r.is_err()),
            _ => assert!(r.is_ok()),
        }
    }
    println!("\nTable 1 matrix reproduced ✓");
}
