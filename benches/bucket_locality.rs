//! Storage-layout axis: per-parameter (scattered) vs bucketed flat
//! update time, across the model zoo — the second fusion axis next to
//! the paper's schedule axis. Bucketing fuses one optimizer dispatch,
//! one lock round and one grad/state allocation walk per *bucket*
//! instead of per *parameter*, which pays off most for models with many
//! small parameters (MobileNetV2-style — the paper's Fig. 6 left end).
//!
//! Output: per model, the baseline-schedule optimizer-stage time and
//! whole-iteration time for scattered storage and for three bucket
//! caps, plus the update-dispatch counts. Losses are asserted
//! bit-identical between layouts (the storage analogue of "the schedule
//! never changes the math").

#[path = "common.rs"]
mod common;

use optfuse::data::image_batch;
use optfuse::exec::kernel::{KernelConfig, KernelMode};
use optfuse::exec::{ExecConfig, Executor};
use optfuse::graph::{Graph, ScheduleKind};
use optfuse::optim::{self, Hyper};
use optfuse::train::{self, RunReport};
use optfuse::util::XorShiftRng;

struct Measured {
    report: RunReport,
    units: usize,
    dispatched: u64,
}

fn measure(
    build: fn(u64) -> Graph,
    kind: ScheduleKind,
    bucket_cap_bytes: Option<usize>,
    batch: usize,
    steps: usize,
) -> Measured {
    measure_kernel(build, kind, bucket_cap_bytes, batch, steps, KernelConfig::default())
}

fn measure_kernel(
    build: fn(u64) -> Graph,
    kind: ScheduleKind,
    bucket_cap_bytes: Option<usize>,
    batch: usize,
    steps: usize,
    kernel: KernelConfig,
) -> Measured {
    let mut ex = Executor::new(
        build(42),
        optim::by_name("adam").unwrap(),
        Hyper { lr: 1e-3, ..Hyper::default() },
        ExecConfig {
            schedule: kind,
            threads: 0,
            race_guard: true,
            bucket_cap_bytes,
            kernel,
            ..Default::default()
        },
    )
    .unwrap();
    let units = ex.graph.store.num_units();
    let mut rng = XorShiftRng::new(9);
    let report = train::run(&mut ex, steps, 1, |_| image_batch(batch, 3, 16, 16, 10, &mut rng));
    Measured { report, units, dispatched: ex.counters.updates_dispatched }
}

fn main() {
    common::header(
        "bucket locality — per-param vs bucketed fused updates (schedule × storage)",
        "flat buckets cut per-parameter dispatch/lock/allocation overhead (Bagua FusedOptimizer, \
         IPEX optimizer fusion)",
    );
    // `--smoke` / OPTFUSE_BENCH_SMOKE=1: reduced zoo and step count so CI
    // can run the harness per-PR and archive the table as an artifact
    let smoke = common::smoke_mode();
    if smoke {
        println!("  (smoke mode: reduced zoo/steps for CI)");
    }

    let full_zoo: &[(&str, fn(u64) -> Graph)] = &[
        ("mobilenet_v2_ish", optfuse::models::mobilenet_v2_ish),
        ("densenet_ish", optfuse::models::densenet_ish),
        ("resnet_ish", optfuse::models::resnet_ish),
        ("mlp", optfuse::models::mlp),
        ("deep_mlp", optfuse::models::deep_mlp),
        ("wide_mlp", optfuse::models::wide_mlp),
    ];
    let zoo = if smoke { &full_zoo[..2] } else { full_zoo };
    let caps: &[(&str, Option<usize>)] = &[
        ("scattered", None),
        ("64KiB", Some(64 << 10)),
        ("1MiB", Some(1 << 20)),
        ("one-bucket", Some(usize::MAX)),
    ];
    let (batch, steps) = if smoke { (8, 2) } else { (16, 5) };

    println!(
        "\n  baseline schedule, adam, batch {batch}, {steps} timed steps; opt = standalone \
         optimizer-stage ms/iter\n"
    );
    println!(
        "  {:<18} {:<10} {:>7} {:>10} {:>10} {:>10}",
        "model", "storage", "units", "opt ms", "iter ms", "disp/step"
    );
    for (name, build) in zoo {
        let mut scattered_losses: Option<Vec<f32>> = None;
        let mut scattered_opt_ms = 0.0;
        for (cap_name, cap) in caps {
            let m = measure(*build, ScheduleKind::Baseline, *cap, batch, steps);
            let (_, _, opt_ms) = m.report.breakdown_ms();
            match &scattered_losses {
                None => {
                    scattered_losses = Some(m.report.losses.clone());
                    scattered_opt_ms = opt_ms;
                }
                Some(want) => assert_eq!(
                    want, &m.report.losses,
                    "{name}/{cap_name}: bucketing must not change training"
                ),
            }
            // counters cover warmup + timed steps; baseline dispatches
            // exactly `units` per step, so the division is exact
            let disp_per_step = m.dispatched / (steps as u64 + 1);
            println!(
                "  {:<18} {:<10} {:>7} {:>10.3} {:>10.2} {:>10}   x{:.2} opt",
                name,
                cap_name,
                m.units,
                opt_ms,
                m.report.iter_ms(),
                disp_per_step,
                scattered_opt_ms / opt_ms.max(1e-9),
            );
        }
        println!();
    }

    // schedule × storage: the fused bucket update also rides inside
    // backward-fusion (inline) — show one model across the grid
    println!("  schedule × storage grid (mobilenet_v2_ish, opt-in-stage ms/iter):\n");
    for kind in ScheduleKind::ALL {
        for (cap_name, cap) in &[("scattered", None), ("1MiB", Some(1usize << 20))] {
            let m = measure(optfuse::models::mobilenet_v2_ish, kind, *cap, batch, steps);
            let (_, _, opt_ms) = m.report.breakdown_ms();
            let fused_ms = (m.report.opt_in_forward + m.report.opt_in_backward).as_secs_f64()
                * 1e3
                / steps as f64;
            println!(
                "    {:<16} {:<10} opt-stage {:>8.3}  fused-in-fwd/bwd {:>8.3}  iter {:>8.2} ms",
                kind.label(),
                cap_name,
                opt_ms,
                fused_ms,
                m.report.iter_ms()
            );
        }
    }
    // ---- kernel-mode axis: scalar vs simd vs simd-mt step time per zoo
    // model, bucketed storage, backward-fusion (the schedule the kernels
    // were built for). This is the acceptance table of the SIMD tentpole:
    // the speedup column is simd/simd-mt step time vs the scalar
    // reference, and losses are asserted bit-identical across modes (the
    // kernel-equivalence contract, live in the harness). The table lands
    // in the CI bench-smoke artifact, so per-mode step time is diffed per
    // PR; ≥2× for at least one model under simd-mt is the PR's bar.
    println!("\n  kernel-mode axis (backward-fusion, 1MiB buckets, adam):\n");
    println!(
        "  {:<18} {:<8} {:>10} {:>10} {:>12}",
        "model", "kernel", "opt ms", "iter ms", "vs scalar"
    );
    for (name, build) in zoo {
        let mut scalar: Option<Measured> = None;
        for mode in KernelMode::ALL {
            let kernel = KernelConfig { mode, lanes: 8, threads: 2 };
            let m = measure_kernel(
                *build,
                ScheduleKind::BackwardFusion,
                Some(1 << 20),
                batch,
                steps,
                kernel,
            );
            let (_, _, opt_ms) = m.report.breakdown_ms();
            let speedup = match &scalar {
                None => 1.0,
                Some(s) => {
                    assert_eq!(
                        s.report.losses, m.report.losses,
                        "{name}/{}: kernel modes must not change training",
                        mode.label()
                    );
                    s.report.iter_ms() / m.report.iter_ms().max(1e-9)
                }
            };
            println!(
                "  {:<18} {:<8} {:>10.3} {:>10.2} {:>11.2}x",
                name,
                mode.label(),
                opt_ms,
                m.report.iter_ms(),
                speedup
            );
            if scalar.is_none() {
                scalar = Some(m);
            }
        }
        println!();
    }

    println!("\nbucket locality bench complete ✓ (losses bit-identical across layouts)");
}
