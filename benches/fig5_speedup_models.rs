//! Fig. 5: training speedup vs mini-batch size across benchmarks
//! (MobileNetV2, DenseNet121, ResNet, VGG19_BN, ...).
//!
//! Paper claims encoded as assertions:
//!  * speedup decreases as mini-batch grows (relative saving shrinks);
//!  * FF and BF converge at large batch;
//!  * MobileNetV2 speeds up most, VGG19_BN barely at all.

#[path = "common.rs"]
mod common;

use optfuse::memsim::{machines, spec::OptSpec, zoo};

fn main() {
    common::header(
        "Fig. 5 — speedup vs mini-batch size, per model",
        "speedup decays with batch; FF/BF converge at large batch; MobileNetV2 best, VGG19_BN ≈1",
    );

    let m = machines::titan_xp();
    let opt = OptSpec::adam();
    let batches = [16usize, 32, 64, 128, 256];

    let mut mob_curve = Vec::new();
    let mut vgg_curve = Vec::new();
    for net in zoo::fig5_models() {
        println!("\n{} ({:.1}M params):", net.name, net.total_params() as f64 / 1e6);
        println!("  batch      FF speedup   BF speedup");
        let mut prev_bf = f64::MAX;
        for &b in &batches {
            let (_, ff, bf) = common::sim_speedups(&m, &net, &opt, b);
            println!("  {b:>5}      {ff:>8.3}     {bf:>8.3}");
            assert!(
                bf <= prev_bf + 0.02,
                "{}: speedup must not grow with batch ({bf:.3} after {prev_bf:.3})",
                net.name
            );
            prev_bf = bf;
            if net.name == "mobilenet_v2" {
                mob_curve.push(bf);
            }
            if net.name == "vgg19_bn" {
                vgg_curve.push(bf);
            }
            if b == 256 {
                assert!(
                    (ff - bf).abs() < 0.06,
                    "{}: FF and BF converge at large batch ({ff:.3} vs {bf:.3})",
                    net.name
                );
            }
        }
    }

    println!("\ncross-model check at bs=32:");
    let mob = mob_curve[1];
    let vgg = vgg_curve[1];
    println!("  mobilenet_v2 BF x{mob:.3}  vs  vgg19_bn BF x{vgg:.3}");
    assert!(mob > vgg, "MobileNetV2 must benefit more than VGG19_BN");
    assert!(vgg < 1.06, "VGG19_BN is 'hardly accelerated' (paper Fig. 6)");
    println!("\nFig. 5 reproduced (shape) ✓");
}
