//! Fig. 4: absolute execution time saved by the fusion methods on
//! MobileNetV2 across mini-batch sizes.
//!
//! Paper claim: once the GPU reaches its roofline, the absolute saved
//! time is (roughly) independent of mini-batch size, because fwd/bwd
//! scale with b while the optimizer does not. Also checks the paper's
//! §C.2 closed-form speedup model against the simulator.

#[path = "common.rs"]
mod common;

use optfuse::graph::ScheduleKind;
use optfuse::memsim::{self, machines, spec::OptSpec, theoretical_speedup, zoo};
use optfuse::models;

fn main() {
    common::header(
        "Fig. 4 — absolute time saved vs mini-batch size (MobileNetV2)",
        "saved ms ≈ flat in batch size once compute dominates",
    );

    let m = machines::titan_xp();
    let net = zoo::mobilenet_v2();
    let opt = OptSpec::adam();
    let batches = [8usize, 16, 32, 64, 128, 256];

    println!("\nsimulated (memsim, TITAN Xp):");
    println!("  batch    baseline(ms)  FF saved(ms)  BF saved(ms)");
    let mut bf_saved = Vec::new();
    for &b in &batches {
        let base = memsim::simulate(&m, &net, &opt, b, ScheduleKind::Baseline);
        let ff = memsim::simulate(&m, &net, &opt, b, ScheduleKind::ForwardFusion);
        let bf = memsim::simulate(&m, &net, &opt, b, ScheduleKind::BackwardFusion);
        let sf = (base.total_s - ff.total_s) * 1e3;
        let sb = (base.total_s - bf.total_s) * 1e3;
        println!("  {b:>5}    {:>10.2}    {sf:>10.2}    {sb:>10.2}", base.total_s * 1e3);
        bf_saved.push(sb);
    }
    // flatness check over the roofline regime (b >= 32)
    let tail = &bf_saved[2..];
    let mean = tail.iter().sum::<f64>() / tail.len() as f64;
    let spread = tail
        .iter()
        .map(|s| (s - mean).abs() / mean)
        .fold(0.0f64, f64::max);
    println!(
        "\n  BF saved-time spread over b∈[32,256]: ±{:.1}% of mean ({mean:.2} ms)",
        spread * 100.0
    );
    assert!(spread < 0.35, "saved time should be roughly batch-independent");

    // paper §C.2 closed-form: s = (b·t_grad + t_opt) / (b·t_grad + t_opt − t_saved)
    println!("\n  §C.2 closed-form speedup vs simulator (BF):");
    let b32 = memsim::simulate(&m, &net, &opt, 32, ScheduleKind::Baseline);
    let t_grad = (b32.forward_s + b32.backward_s) / 32.0;
    let t_opt = b32.optimizer_s;
    println!("  batch   formula   simulated");
    for &b in &batches {
        let base = memsim::simulate(&m, &net, &opt, b, ScheduleKind::Baseline);
        let bf = memsim::simulate(&m, &net, &opt, b, ScheduleKind::BackwardFusion);
        let simulated = base.total_s / bf.total_s;
        let formula = theoretical_speedup(b as f64, t_grad, t_opt, mean / 1e3);
        println!("  {b:>5}   {formula:>7.3}   {simulated:>9.3}");
        assert!((formula - simulated).abs() < 0.12, "model and sim must agree");
    }

    // measured counterpart: deep_mlp (many small layers) on this host
    println!("\nmeasured on this host (deep_mlp, adam, inline BF — locality only):");
    println!("  batch    baseline(ms)   BF saved(ms)");
    for &b in &[1usize, 2, 4, 8, 16] {
        let base = common::measure(models::deep_mlp, ScheduleKind::Baseline, "adam", b, 8, 0);
        let bf = common::measure(models::deep_mlp, ScheduleKind::BackwardFusion, "adam", b, 8, 0);
        println!(
            "  {b:>5}    {:>10.2}    {:>10.2}",
            base.iter_ms(),
            base.iter_ms() - bf.iter_ms()
        );
    }
    println!("\nFig. 4 reproduced (shape) ✓");
}
