//! §C.4 (text): Transformer (base) on WMT En-De, mini-batch 256:
//! forward-fusion 1.030×, backward-fusion 1.019×.
//!
//! Big batch + huge layers ⇒ tiny speedups, the other extreme from
//! MobileNetV2 — the interesting part is reproducing *how small* the
//! gain is.

#[path = "common.rs"]
mod common;

use optfuse::exec::{ExecConfig, Executor};
use optfuse::graph::ScheduleKind;
use optfuse::memsim::{machines, spec::OptSpec, zoo};
use optfuse::models::transformer::{token_batch, transformer_lm};
use optfuse::models::TransformerCfg;
use optfuse::optim::{AdamW, Hyper};
use optfuse::util::XorShiftRng;

fn main() {
    common::header(
        "§C.4 — Transformer base, WMT En-De, bs=256",
        "FF 1.030x, BF 1.019x (small but real)",
    );

    let m = machines::titan_xp();
    let net = zoo::transformer_base();
    let opt = OptSpec::adam();
    println!(
        "\nsimulated (memsim, TITAN Xp, {:.0}M params):",
        net.total_params() as f64 / 1e6
    );
    // bs=256 sentences ≈ 256*~27 tokens; our per-item unit is one token,
    // so sweep the token-batch around the paper's effective size.
    println!("  token-batch    FF speedup   BF speedup");
    let mut at_paper_scale = (0.0, 0.0);
    for &b in &[1024usize, 4096, 8192] {
        let (_, ff, bf) = common::sim_speedups(&m, &net, &opt, b);
        println!("  {b:>9}      {ff:>8.3}     {bf:>8.3}");
        if b == 8192 {
            at_paper_scale = (ff, bf);
        }
    }
    let (ff, bf) = at_paper_scale;
    assert!(ff > 1.0 && ff < 1.08, "FF small-but-positive: {ff:.3}");
    assert!(bf > 1.0 && bf < 1.08, "BF small-but-positive: {bf:.3}");
    println!(
        "\n  at the paper's effective batch: FF x{ff:.3}, BF x{bf:.3} (paper: 1.030 / 1.019) — \
         same 'few-percent' regime ✓"
    );

    // measured: the real small transformer trains identically under all
    // schedules; report wallclock for the record
    println!("\nmeasured on this host (transformer small, bs=4, 5 steps):");
    let cfg = TransformerCfg { layers: 2, seq: 32, ..TransformerCfg::small() };
    let corpus: Vec<u8> = (0..4096u32).map(|i| (i * 37 % 251) as u8).collect();
    let mut base_losses = Vec::new();
    for kind in ScheduleKind::ALL {
        let mut ex = Executor::new(
            transformer_lm(&cfg, 11),
            Box::new(AdamW),
            Hyper::default(),
            ExecConfig { schedule: kind, threads: 0, race_guard: true, ..Default::default() },
        )
        .unwrap();
        let mut rng = XorShiftRng::new(6);
        let t0 = std::time::Instant::now();
        let mut losses = Vec::new();
        for _ in 0..5 {
            let b = token_batch(&cfg, 4, &corpus, &mut rng);
            losses.push(ex.train_step(&b).loss);
        }
        println!(
            "  {:<16} {:.2} ms/iter  final loss {:.4}",
            kind.label(),
            t0.elapsed().as_secs_f64() * 1e3 / 5.0,
            losses.last().unwrap()
        );
        if kind == ScheduleKind::Baseline {
            base_losses = losses;
        } else {
            assert_eq!(losses, base_losses, "schedules must agree");
        }
    }
    println!("\n§C.4 reproduced (shape) ✓");
}
