//! Ablations called out in DESIGN.md §5:
//!  (a) control-flow overhead of Algs. 2–3 (the paper's §C.2 explanation
//!      for small-batch slowdown) — measured via the executor's counters
//!      and tiny-batch wallclock;
//!  (b) the §B.2 race guard: correctness cost of the safe ordering;
//!  (c) BF worker-pool width;
//!  (d) fused vs unfused optimizer update — the single-pass Pallas-style
//!      kernel vs the eager one-primitive-per-pass form (Apex motivation).

#[path = "common.rs"]
mod common;

use optfuse::data::image_batch;
use optfuse::exec::{ExecConfig, Executor};
use optfuse::graph::ScheduleKind;
use optfuse::models;
use optfuse::optim::{self, Hyper};
use optfuse::util::{timer::bench_mean, XorShiftRng};

fn main() {
    common::header(
        "Ablations — scheduler overhead, race guard, pool width, fused update",
        "§C.2: control overhead must be amortized by batch size; Apex-style fusion",
    );

    // (a) control counters + small-batch relative cost
    println!("\n(a) schedule control overhead (deep_mlp, adam):");
    let mut ex = Executor::new(
        models::deep_mlp(1),
        optim::by_name("adam").unwrap(),
        Hyper::default(),
        ExecConfig {
            schedule: ScheduleKind::BackwardFusion,
            threads: 0,
            race_guard: true,
            ..Default::default()
        },
    )
    .unwrap();
    let mut rng = XorShiftRng::new(2);
    let b = image_batch(2, 3, 16, 16, 10, &mut rng);
    ex.train_step(&b);
    println!(
        "  per step: {} refcount ops, {} updates — bookkeeping is O(params), independent of batch",
        ex.counters.refcount_ops, ex.counters.updates_dispatched
    );
    println!("  batch    baseline ms    BF ms    BF/baseline");
    for &bsz in &[1usize, 8, 32] {
        let base = common::measure(models::deep_mlp, ScheduleKind::Baseline, "adam", bsz, 6, 0);
        let bf = common::measure(models::deep_mlp, ScheduleKind::BackwardFusion, "adam", bsz, 6, 0);
        println!(
            "  {bsz:>5}    {:>9.2}    {:>7.2}    {:>6.3}",
            base.iter_ms(),
            bf.iter_ms(),
            bf.iter_ms() / base.iter_ms()
        );
    }

    // (b) race guard cost (correct vs naive-buggy ordering wallclock)
    println!("\n(b) §B.2 race guard (BF inline, deep_mlp bs=4):");
    for guard in [true, false] {
        let mut ex = Executor::new(
            models::deep_mlp(1),
            optim::by_name("sgd").unwrap(),
            Hyper::default(),
            ExecConfig {
                schedule: ScheduleKind::BackwardFusion,
                threads: 0,
                race_guard: guard,
                ..Default::default()
            },
        )
        .unwrap();
        let mut rng = XorShiftRng::new(3);
        let b = image_batch(4, 3, 16, 16, 10, &mut rng);
        let d = bench_mean(6, 2, || {
            ex.train_step(&b);
        });
        println!(
            "  race_guard={guard:<5}  {:.2} ms/iter   ({})",
            d.as_secs_f64() * 1e3,
            if guard { "correct ordering" } else { "NAIVE — corrupts ∂L/∂x, do not use" }
        );
    }
    println!(
        "  → the safe ordering costs nothing: it only *positions* the update after the \
         node's backward"
    );

    // (c) pool width (single-core host: expect flat/overhead-only — the
    //     multi-core benefit is quantified by memsim's overlap model)
    println!("\n(c) BF worker-pool width (deep_mlp bs=4; 1-core host):");
    for threads in [0usize, 1, 2, 4] {
        let bf =
            common::measure(models::deep_mlp, ScheduleKind::BackwardFusion, "adam", 4, 6, threads);
        println!("  threads={threads}   {:.2} ms/iter", bf.iter_ms());
    }

    // (d) fused vs unfused update: one pass over θ,g,m,v vs one pass per
    //     primitive (the traffic amplification memsim charges unfused)
    println!("\n(d) fused vs unfused Adam update (4M-element parameter):");
    let n = 4 << 20;
    let mut theta = vec![0.5f32; n];
    let mut g = vec![0.1f32; n];
    let mut m1 = vec![0.0f32; n];
    let mut v1 = vec![0.0f32; n];
    let (lr, b1, b2, eps) = (1e-3f32, 0.9f32, 0.999f32, 1e-8f32);
    let fused = bench_mean(5, 1, || {
        for i in 0..n {
            let gr = g[i];
            m1[i] = b1 * m1[i] + (1.0 - b1) * gr;
            v1[i] = b2 * v1[i] + (1.0 - b2) * gr * gr;
            theta[i] -= lr * m1[i] / (v1[i].sqrt() + eps);
            g[i] = 0.0;
        }
    });
    let unfused = bench_mean(5, 1, || {
        // one primitive per pass, operands re-streamed (eager semantics)
        for i in 0..n {
            m1[i] *= b1;
        }
        for i in 0..n {
            m1[i] += (1.0 - b1) * g[i];
        }
        for i in 0..n {
            v1[i] *= b2;
        }
        for i in 0..n {
            v1[i] += (1.0 - b2) * g[i] * g[i];
        }
        let mut tmp = vec![0.0f32; n];
        for i in 0..n {
            tmp[i] = v1[i].sqrt() + eps;
        }
        for i in 0..n {
            tmp[i] = m1[i] / tmp[i];
        }
        for i in 0..n {
            theta[i] -= lr * tmp[i];
        }
        for i in 0..n {
            g[i] = 0.0;
        }
    });
    let speedup = unfused.as_secs_f64() / fused.as_secs_f64();
    println!(
        "  fused {:.2} ms   unfused {:.2} ms   fusion speedup x{speedup:.2}",
        fused.as_secs_f64() * 1e3,
        unfused.as_secs_f64() * 1e3
    );
    assert!(speedup > 1.2, "single-pass update must beat multi-pass: x{speedup:.2}");
    println!("\nablations complete ✓");
}
