"""AOT pipeline: every manifest entry lowers to parseable HLO text, and
the manifest faithfully describes the artifacts."""

import json
import os

import pytest

from compile import aot
from compile.manifest_spec import ENTRIES

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@pytest.mark.parametrize("name", sorted(ENTRIES))
def test_entry_lowers_to_hlo_text(name):
    text, n_out = aot.lower_entry(name)
    assert "ENTRY" in text, "must be XLA HLO text"
    assert "HloModule" in text
    assert n_out >= 1
    # 64-bit-id proto issue is avoided by the text path; text has no ids
    # beyond instruction-local %names, so a quick sanity on structure:
    assert text.count("ROOT") >= 1


def test_build_writes_manifest(tmp_path):
    # lower only the two smallest entries into a temp dir via a trimmed
    # ENTRIES view (monkeypatching keeps the full build for `make artifacts`)
    m = aot.build(str(tmp_path))
    files = os.listdir(tmp_path)
    assert "manifest.json" in files
    data = json.loads((tmp_path / "manifest.json").read_text())
    assert data["format"] == 1
    assert len(data["artifacts"]) == len(ENTRIES)
    for a in data["artifacts"]:
        assert (tmp_path / a["file"]).exists()
        assert a["outputs"] >= 1
        assert all(isinstance(s, list) for s in a["inputs"])
    assert m["artifacts"] == data["artifacts"]


def test_checked_in_artifacts_match_manifest():
    """If `make artifacts` has run, the manifest must be consistent."""
    mpath = os.path.join(ART, "manifest.json")
    if not os.path.exists(mpath):
        pytest.skip("artifacts not built yet")
    data = json.loads(open(mpath).read())
    for a in data["artifacts"]:
        path = os.path.join(ART, a["file"])
        assert os.path.exists(path), a["file"]
        head = open(path).read(200)
        assert "HloModule" in head
