"""L2 correctness: the fused train-step module and the FFN block."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model


def _data(seed=0, b=8, din=64, dh=32, dout=10):
    rng = np.random.default_rng(seed)
    a = lambda *s: jnp.asarray(rng.normal(size=s).astype("float32"))
    return a(b, din), a(b, dout), a(din, dh) * 0.2, a(dh, dout) * 0.2


def test_mlp_train_step_shapes():
    x, y, w1, w2 = _data()
    loss, w1n, w2n = model.mlp_train_step(x, y, w1, w2)
    assert loss.shape == (1,)
    assert w1n.shape == w1.shape and w2n.shape == w2.shape


def test_mlp_train_step_decreases_loss():
    x, y, w1, w2 = _data(1)
    losses = []
    for _ in range(20):
        loss, w1, w2 = model.mlp_train_step(x, y, w1, w2, lr=0.05)
        losses.append(float(loss[0]))
    assert losses[-1] < losses[0] * 0.9, losses


def test_mlp_train_step_matches_manual_sgd():
    """Fused module == plain jax grad + manual SGD (the rust engine's
    baseline semantics)."""
    x, y, w1, w2 = _data(2)

    def loss_fn(w1_, w2_):
        pred = jnp.maximum(x @ w1_, 0.0) @ w2_
        return jnp.mean((pred - y) ** 2)

    l0, (g1, g2) = (
        jax.value_and_grad(loss_fn, argnums=(0, 1))(w1, w2)[0],
        jax.grad(loss_fn, argnums=(0, 1))(w1, w2),
    )
    loss, w1n, w2n = model.mlp_train_step(x, y, w1, w2, lr=0.05)
    np.testing.assert_allclose(float(loss[0]), float(l0), rtol=1e-6)
    np.testing.assert_allclose(w1n, w1 - 0.05 * g1, rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(w2n, w2 - 0.05 * g2, rtol=1e-5, atol=1e-7)


def test_ffn_block_matches_reference():
    rng = np.random.default_rng(3)
    a = lambda *s: jnp.asarray(rng.normal(size=s).astype("float32"))
    x, gamma, beta = a(16, 32), a(32) * 0.1 + 1.0, a(32) * 0.1
    w1, b1, w2, b2 = a(32, 128) * 0.1, a(128) * 0.1, a(128, 32) * 0.1, a(32) * 0.1
    (out,) = model.ffn_block(x, gamma, beta, w1, b1, w2, b2)
    assert out.shape == x.shape
    # residual: zero weights => identity
    z = jnp.zeros
    (ident,) = model.ffn_block(x, gamma, beta, z((32, 128)), z(128), z((128, 32)), z(32))
    np.testing.assert_allclose(ident, x, rtol=1e-6)


def test_ffn_block_layernorm_is_normalizing():
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.normal(size=(4, 64)).astype("float32")) * 10.0
    gamma, beta = jnp.ones(64), jnp.zeros(64)
    # tap the normalized value by using identity-ish ffn and subtracting x
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    h = (x - mu) / jnp.sqrt(var + 1e-5)
    np.testing.assert_allclose(jnp.mean(h, axis=-1), 0.0, atol=1e-5)
