"""L1 correctness: every Pallas kernel vs its pure-jnp oracle, swept over
shapes and hyper-parameters with hypothesis (DESIGN.md invariant 5)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    adamw_update,
    bwd_matmul_sgd,
    fwd_update_matmul,
    ref,
    sgd_update,
    sgdm_update,
)

DIMS = st.sampled_from([1, 2, 3, 5, 8, 16, 17, 32, 64, 96, 128, 130, 256])
SMALL = st.sampled_from([1, 2, 4, 8, 16, 24, 32])
LR = st.sampled_from([1e-3, 1e-2, 0.1])
WD = st.sampled_from([0.0, 1e-2, 0.1])


def arr(rng, *shape):
    return jnp.asarray(rng.normal(size=shape).astype("float32"))


@settings(max_examples=25, deadline=None)
@given(r=DIMS, c=DIMS, lr=LR, wd=WD, seed=st.integers(0, 2**16))
def test_sgd_matches_ref(r, c, lr, wd, seed):
    rng = np.random.default_rng(seed)
    t, g = arr(rng, r, c), arr(rng, r, c)
    got = sgd_update(t, g, lr=lr, wd=wd)
    want = ref.sgd_ref(t, g, lr, wd)
    for a, b in zip(got, want):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(r=DIMS, c=DIMS, lr=LR, wd=WD, mu=st.sampled_from([0.0, 0.5, 0.9]),
       seed=st.integers(0, 2**16))
def test_sgdm_matches_ref(r, c, lr, wd, mu, seed):
    rng = np.random.default_rng(seed)
    t, g, m = arr(rng, r, c), arr(rng, r, c), arr(rng, r, c)
    got = sgdm_update(t, g, m, lr=lr, mu=mu, wd=wd)
    want = ref.sgdm_ref(t, g, m, lr, mu, wd)
    for a, b in zip(got, want):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(r=DIMS, c=DIMS, step=st.integers(1, 100), seed=st.integers(0, 2**16))
def test_adamw_matches_ref(r, c, step, seed):
    rng = np.random.default_rng(seed)
    t, g = arr(rng, r, c), arr(rng, r, c)
    m, v = arr(rng, r, c) * 0.1, jnp.abs(arr(rng, r, c)) * 0.1
    kw = dict(lr=1e-3, b1=0.9, b2=0.999, eps=1e-8, wd=1e-2)
    got = adamw_update(t, g, m, v, float(step), **kw)
    want = ref.adamw_ref(t, g, m, v, float(step), *kw.values())
    for a, b in zip(got, want):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(m=SMALL, k=SMALL, n=DIMS, lr=LR, wd=WD, seed=st.integers(0, 2**16))
def test_bwd_matmul_sgd_matches_ref(m, k, n, lr, wd, seed):
    rng = np.random.default_rng(seed)
    x, dy, w = arr(rng, m, k), arr(rng, m, n), arr(rng, k, n)
    dx, w2 = bwd_matmul_sgd(x, dy, w, lr=lr, wd=wd)
    rdx, rw2 = ref.bwd_matmul_sgd_ref(x, dy, w, lr, wd)
    np.testing.assert_allclose(dx, rdx, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(w2, rw2, rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(m=SMALL, k=SMALL, n=DIMS, lr=LR, seed=st.integers(0, 2**16))
def test_fwd_update_matmul_matches_ref(m, k, n, lr, seed):
    rng = np.random.default_rng(seed)
    x, w = arr(rng, m, k), arr(rng, k, n)
    g, mom = arr(rng, k, n), arr(rng, k, n)
    got = fwd_update_matmul(x, w, g, mom, lr=lr, mu=0.9, wd=1e-2)
    want = ref.fwd_update_matmul_ref(x, w, g, mom, lr, 0.9, 1e-2)
    for a, b in zip(got, want):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


def test_bwd_fused_uses_old_weight():
    """The §B.2 race rule holds *inside* the fused kernel: dx must be
    computed from the pre-update weight."""
    rng = np.random.default_rng(1)
    x, dy, w = arr(rng, 4, 4), arr(rng, 4, 4), arr(rng, 4, 4)
    dx, w2 = bwd_matmul_sgd(x, dy, w, lr=0.5, wd=0.0)  # big lr: w2 far from w
    np.testing.assert_allclose(dx, dy @ w.T, rtol=1e-5, atol=1e-6)
    with pytest.raises(AssertionError):
        np.testing.assert_allclose(dx, dy @ w2.T, rtol=1e-3, atol=1e-3)


def test_sgd_resets_grad():
    rng = np.random.default_rng(2)
    t, g = arr(rng, 8, 8), arr(rng, 8, 8)
    _, g2 = sgd_update(t, g, lr=0.1, wd=0.0)
    assert float(jnp.max(jnp.abs(g2))) == 0.0


def test_adamw_step_dependence():
    """Bias correction must make step 1 and step 10 differ."""
    rng = np.random.default_rng(3)
    t, g = arr(rng, 8, 8), arr(rng, 8, 8)
    m = jnp.zeros_like(t)
    v = jnp.zeros_like(t)
    kw = dict(lr=1e-3, b1=0.9, b2=0.999, eps=1e-8, wd=0.0)
    t1 = adamw_update(t, g, m, v, 1.0, **kw)[0]
    t10 = adamw_update(t, g, m, v, 10.0, **kw)[0]
    assert float(jnp.max(jnp.abs(t1 - t10))) > 1e-6


@settings(max_examples=20, deadline=None)
@given(r=DIMS, c=DIMS, lr=LR, wd=WD, seed=st.integers(0, 2**16))
def test_adagrad_matches_ref(r, c, lr, wd, seed):
    from compile.kernels import adagrad_update

    rng = np.random.default_rng(seed)
    t, g = arr(rng, r, c), arr(rng, r, c)
    h = jnp.abs(arr(rng, r, c)) * 0.1
    got = adagrad_update(t, g, h, lr=lr, eps=1e-8, wd=wd)
    want = ref.adagrad_ref(t, g, h, lr, 1e-8, wd)
    for a, b in zip(got, want):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(r=DIMS, c=DIMS, lr=LR, rho=st.sampled_from([0.0, 0.9, 0.99]),
       seed=st.integers(0, 2**16))
def test_rmsprop_matches_ref(r, c, lr, rho, seed):
    from compile.kernels import rmsprop_update

    rng = np.random.default_rng(seed)
    t, g = arr(rng, r, c), arr(rng, r, c)
    v = jnp.abs(arr(rng, r, c)) * 0.1
    got = rmsprop_update(t, g, v, lr=lr, rho=rho, eps=1e-8, wd=1e-2)
    want = ref.rmsprop_ref(t, g, v, lr, rho, 1e-8, 1e-2)
    for a, b in zip(got, want):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)
