"""L2: JAX compute graphs that call the L1 Pallas kernels.

These are the computations that get AOT-lowered to HLO text and executed
from the rust coordinator (build-time only — python never runs on the
training hot path).

The flagship entry point is `mlp_train_step`: a *whole fused training
iteration* (forward → backward → fused optimizer update) of a 2-layer MLP
as one XLA module, numerically identical to the rust engine's native
baseline — the integration tests in rust/tests/ verify exactly that.
"""

import jax
import jax.numpy as jnp

from .kernels import (
    adagrad_update,
    adamw_update,
    bwd_matmul_sgd,
    fwd_update_matmul,
    rmsprop_update,
    sgd_update,
    sgdm_update,
)


# ----------------------------------------------------------------------
# Fused MLP train step (SGD, MSE loss) — matches the rust engine's
# mlp/MseLoss semantics for the cross-validation test.
# ----------------------------------------------------------------------

def mlp_train_step(x, y, w1, w2, *, lr=0.05):
    """One training iteration of  y_hat = relu(x@w1)@w2  under MSE loss.

    Returns (loss, w1', w2'). Gradients via jax.grad; the parameter
    updates run through the fused Pallas SGD kernel.
    """

    def loss_fn(params):
        w1_, w2_ = params
        h = jnp.maximum(x @ w1_, 0.0)
        pred = h @ w2_
        return jnp.mean((pred - y) ** 2)

    loss, (g1, g2) = jax.value_and_grad(loss_fn)((w1, w2))
    w1n, _ = sgd_update(w1, g1, lr=lr, wd=0.0)
    w2n, _ = sgd_update(w2, g2, lr=lr, wd=0.0)
    return loss.reshape(1), w1n, w2n


# ----------------------------------------------------------------------
# Transformer FFN block forward (LayerNorm -> Linear -> GELU -> Linear ->
# residual): the L2 building block a serving-side runtime would call.
# ----------------------------------------------------------------------

def ffn_block(x, gamma, beta, w1, b1, w2, b2):
    """Pre-LN feed-forward block, [tokens, d] -> [tokens, d]."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    h = (x - mu) / jnp.sqrt(var + 1e-5) * gamma + beta
    h = h @ w1 + b1
    h = 0.5 * h * (1.0 + jnp.tanh(0.7978845608 * (h + 0.044715 * h**3)))
    h = h @ w2 + b2
    return (x + h,)


# ----------------------------------------------------------------------
# Thin wrappers so AOT entries are plain shape-to-shape functions.
# ----------------------------------------------------------------------

def adamw_entry(theta, grad, m, v, step, *, lr=1e-3, b1=0.9, b2=0.999,
                eps=1e-8, wd=1e-2):
    return adamw_update(theta, grad, m, v, step, lr=lr, b1=b1, b2=b2,
                        eps=eps, wd=wd)


def sgdm_entry(theta, grad, m, *, lr=1e-3, mu=0.9, wd=1e-2):
    return sgdm_update(theta, grad, m, lr=lr, mu=mu, wd=wd)


def bwd_fused_entry(x, dy, w, *, lr=1e-2, wd=0.0):
    return bwd_matmul_sgd(x, dy, w, lr=lr, wd=wd)


def fwd_fused_entry(x, w, grad, m, *, lr=1e-2, mu=0.9, wd=0.0):
    return fwd_update_matmul(x, w, grad, m, lr=lr, mu=mu, wd=wd)


def adagrad_entry(theta, grad, h, *, lr=1e-2, eps=1e-8, wd=1e-2):
    return adagrad_update(theta, grad, h, lr=lr, eps=eps, wd=wd)


def rmsprop_entry(theta, grad, v, *, lr=1e-3, rho=0.9, eps=1e-8, wd=1e-2):
    return rmsprop_update(theta, grad, v, lr=lr, rho=rho, eps=eps, wd=wd)
