"""Pallas L1 kernels (interpret mode) + pure-jnp oracles."""
from . import ref  # noqa: F401
from .fused_matmul import bwd_matmul_sgd, fwd_update_matmul  # noqa: F401
from .fused_update import (  # noqa: F401
    adagrad_update,
    adamw_update,
    rmsprop_update,
    sgd_update,
    sgdm_update,
)
