"""L1 Pallas kernels: fused optimizer updates.

The paper's eager baseline launches one elementwise kernel per primitive
op of the update rule (PyTorch-style), re-streaming every operand from
HBM each time. These kernels are the single-pass fused form the fusion
schedules rely on: each operand tile is read once into VMEM, the whole
update happens on-chip, and each operand is written once.

TPU adaptation (DESIGN.md §3): the GPU cache-line locality argument
becomes VMEM residency — BlockSpec tiles θ/g/m/v so one (block_r × block_c)
tile of each operand is resident per grid step. VMEM footprint per step is
`slots × block_r × block_c × 4` bytes; with the default 128×128 f32 blocks
that is 256 KiB for AdamW (4 operands) — far under the ~16 MiB budget,
leaving room for double-buffering.

All kernels run with interpret=True: real-TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute (see /opt/xla-example).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True


def _block(dim, pref=128):
    """Largest divisor of `dim` that is <= pref (keeps grids exact)."""
    b = min(dim, pref)
    while dim % b:
        b -= 1
    return b


def _grid_2d(shape, pref=128):
    r, c = shape
    br, bc = _block(r, pref), _block(c, pref)
    return (r // br, c // bc), (br, bc)


def _tile_spec(br, bc):
    return pl.BlockSpec((br, bc), lambda i, j: (i, j))


# ----------------------------------------------------------------------
# SGD
# ----------------------------------------------------------------------

def _sgd_kernel(t_ref, g_ref, t_out, g_out, *, lr, wd):
    g = g_ref[...] + wd * t_ref[...]
    t_out[...] = t_ref[...] - lr * g
    g_out[...] = jnp.zeros_like(g_ref[...])


def sgd_update(theta, grad, *, lr, wd):
    """Single-pass fused SGD: returns (theta', grad'=0)."""
    (gr, gc), (br, bc) = _grid_2d(theta.shape)
    spec = _tile_spec(br, bc)
    return pl.pallas_call(
        functools.partial(_sgd_kernel, lr=lr, wd=wd),
        grid=(gr, gc),
        in_specs=[spec, spec],
        out_specs=[spec, spec],
        out_shape=[
            jax.ShapeDtypeStruct(theta.shape, theta.dtype),
            jax.ShapeDtypeStruct(grad.shape, grad.dtype),
        ],
        interpret=INTERPRET,
    )(theta, grad)


# ----------------------------------------------------------------------
# SGD + momentum
# ----------------------------------------------------------------------

def _sgdm_kernel(t_ref, g_ref, m_ref, t_out, g_out, m_out, *, lr, mu, wd):
    g = g_ref[...] + wd * t_ref[...]
    m2 = mu * m_ref[...] + g
    t_out[...] = t_ref[...] - lr * m2
    g_out[...] = jnp.zeros_like(g_ref[...])
    m_out[...] = m2


def sgdm_update(theta, grad, m, *, lr, mu, wd):
    """Fused heavy-ball momentum: returns (theta', grad'=0, m')."""
    (gr, gc), (br, bc) = _grid_2d(theta.shape)
    spec = _tile_spec(br, bc)
    out = jax.ShapeDtypeStruct(theta.shape, theta.dtype)
    return pl.pallas_call(
        functools.partial(_sgdm_kernel, lr=lr, mu=mu, wd=wd),
        grid=(gr, gc),
        in_specs=[spec] * 3,
        out_specs=[spec] * 3,
        out_shape=[out, out, out],
        interpret=INTERPRET,
    )(theta, grad, m)


# ----------------------------------------------------------------------
# AdamW (decoupled weight decay); step is a runtime scalar for bias
# correction.
# ----------------------------------------------------------------------

def _adamw_kernel(step_ref, t_ref, g_ref, m_ref, v_ref,
                  t_out, g_out, m_out, v_out, *, lr, b1, b2, eps, wd):
    step = step_ref[0, 0]
    g = g_ref[...]
    t = t_ref[...] * (1.0 - lr * wd)
    m2 = b1 * m_ref[...] + (1.0 - b1) * g
    v2 = b2 * v_ref[...] + (1.0 - b2) * g * g
    bc1 = 1.0 - b1 ** step
    bc2 = 1.0 - b2 ** step
    mhat = m2 / bc1
    vhat = v2 / bc2
    t_out[...] = t - lr * mhat / (jnp.sqrt(vhat) + eps)
    g_out[...] = jnp.zeros_like(g)
    m_out[...] = m2
    v_out[...] = v2


def adamw_update(theta, grad, m, v, step, *, lr, b1, b2, eps, wd):
    """Fused AdamW. `step` is a float32 scalar array (1-based).

    Returns (theta', grad'=0, m', v'). One read + one write per operand —
    vs. ~10 kernel launches and ~2.5× the traffic for the unfused eager
    form (see memsim::spec::OptSpec::adamw).
    """
    (gr, gc), (br, bc) = _grid_2d(theta.shape)
    spec = _tile_spec(br, bc)
    # the step scalar is broadcast to every grid cell (SMEM-style operand)
    sspec = pl.BlockSpec((1, 1), lambda i, j: (0, 0))
    out = jax.ShapeDtypeStruct(theta.shape, theta.dtype)
    step_arr = jnp.asarray(step, jnp.float32).reshape(1, 1)
    return pl.pallas_call(
        functools.partial(_adamw_kernel, lr=lr, b1=b1, b2=b2, eps=eps, wd=wd),
        grid=(gr, gc),
        in_specs=[sspec] + [spec] * 4,
        out_specs=[spec] * 4,
        out_shape=[out, out, out, out],
        interpret=INTERPRET,
    )(step_arr, theta, grad, m, v)


# ----------------------------------------------------------------------
# Adagrad (Duchi et al. 2011)
# ----------------------------------------------------------------------

def _adagrad_kernel(t_ref, g_ref, h_ref, t_out, g_out, h_out, *, lr, eps, wd):
    g = g_ref[...] + wd * t_ref[...]
    h2 = h_ref[...] + g * g
    t_out[...] = t_ref[...] - lr * g / (jnp.sqrt(h2) + eps)
    g_out[...] = jnp.zeros_like(g_ref[...])
    h_out[...] = h2


def adagrad_update(theta, grad, h, *, lr, eps, wd):
    """Fused Adagrad: returns (theta', grad'=0, h')."""
    (gr, gc), (br, bc) = _grid_2d(theta.shape)
    spec = _tile_spec(br, bc)
    out = jax.ShapeDtypeStruct(theta.shape, theta.dtype)
    return pl.pallas_call(
        functools.partial(_adagrad_kernel, lr=lr, eps=eps, wd=wd),
        grid=(gr, gc),
        in_specs=[spec] * 3,
        out_specs=[spec] * 3,
        out_shape=[out, out, out],
        interpret=INTERPRET,
    )(theta, grad, h)


# ----------------------------------------------------------------------
# RMSprop
# ----------------------------------------------------------------------

def _rmsprop_kernel(t_ref, g_ref, v_ref, t_out, g_out, v_out, *, lr, rho, eps, wd):
    g = g_ref[...] + wd * t_ref[...]
    v2 = rho * v_ref[...] + (1.0 - rho) * g * g
    t_out[...] = t_ref[...] - lr * g / (jnp.sqrt(v2) + eps)
    g_out[...] = jnp.zeros_like(g_ref[...])
    v_out[...] = v2


def rmsprop_update(theta, grad, v, *, lr, rho, eps, wd):
    """Fused RMSprop: returns (theta', grad'=0, v')."""
    (gr, gc), (br, bc) = _grid_2d(theta.shape)
    spec = _tile_spec(br, bc)
    out = jax.ShapeDtypeStruct(theta.shape, theta.dtype)
    return pl.pallas_call(
        functools.partial(_rmsprop_kernel, lr=lr, rho=rho, eps=eps, wd=wd),
        grid=(gr, gc),
        in_specs=[spec] * 3,
        out_specs=[spec] * 3,
        out_shape=[out, out, out],
        interpret=INTERPRET,
    )(theta, grad, v)
