"""L1 Pallas kernels: optimizer updates fused with adjacent matmuls —
the paper's two schedule rewrites expressed at kernel granularity.

* `bwd_matmul_sgd` (backward-fusion, Fig. 1d): one kernel computes the
  layer's input gradient dX = dY·Wᵀ, the weight gradient dW = Xᵀ·dY, and
  applies the SGD update to W — dW never round-trips to HBM, and the
  kernel reads W exactly once, *before* overwriting it (the §B.2 race
  rule enforced by construction inside one kernel).

* `fwd_update_matmul` (forward-fusion, Fig. 1c): one kernel applies the
  pending momentum update to W and immediately uses the fresh tile for
  the next forward matmul — the update's write merges with the forward's
  read while the tile is still in VMEM (the purple frame of Fig. 2).

TPU adaptation: the grid walks N-tiles of W; each step holds one
(K × block_n) W-tile plus the full X in VMEM and drives the MXU with the
f32 matmul. For the default block_n=128 and K≤512, VMEM per step is
K·128·4·(#operands) ≈ 1 MiB — double-bufferable under the 16 MiB budget.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .fused_update import INTERPRET, _block


# ----------------------------------------------------------------------
# backward-fusion kernel
# ----------------------------------------------------------------------

def _bwd_kernel(x_ref, dy_ref, w_ref, dx_ref, w_out, *, lr, wd):
    j = pl.program_id(0)
    # dX accumulates over N-tiles; initialize on the first tile.
    @pl.when(j == 0)
    def _init():
        dx_ref[...] = jnp.zeros_like(dx_ref)

    dy = dy_ref[...]          # [M, bn]
    w = w_ref[...]            # [K, bn]  — read BEFORE the in-place update
    dx_ref[...] += dy @ w.T   # [M, K]
    dw = x_ref[...].T @ dy    # [K, bn]; stays in VMEM
    w_out[...] = w - lr * (dw + wd * w)


def bwd_matmul_sgd(x, dy, w, *, lr, wd):
    """Fused backward + SGD for y = x@w. Returns (dx, w').

    x: [M, K], dy: [M, N], w: [K, N].
    """
    m, k = x.shape
    _, n = dy.shape
    bn = _block(n)
    grid = (n // bn,)
    return pl.pallas_call(
        functools.partial(_bwd_kernel, lr=lr, wd=wd),
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, k), lambda j: (0, 0)),   # X: whole, resident
            pl.BlockSpec((m, bn), lambda j: (0, j)),  # dY tile
            pl.BlockSpec((k, bn), lambda j: (0, j)),  # W tile
        ],
        out_specs=[
            pl.BlockSpec((m, k), lambda j: (0, 0)),   # dX accumulator
            pl.BlockSpec((k, bn), lambda j: (0, j)),  # W' tile
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, k), x.dtype),
            jax.ShapeDtypeStruct((k, n), w.dtype),
        ],
        interpret=INTERPRET,
    )(x, dy, w)


# ----------------------------------------------------------------------
# forward-fusion kernel
# ----------------------------------------------------------------------

def _fwd_kernel(x_ref, w_ref, g_ref, m_ref, y_ref, w_out, g_out, m_out,
                *, lr, mu, wd):
    w = w_ref[...]
    g = g_ref[...] + wd * w
    m2 = mu * m_ref[...] + g
    w2 = w - lr * m2
    w_out[...] = w2
    g_out[...] = jnp.zeros_like(g_ref[...])
    m_out[...] = m2
    # forward consumes the freshly-updated tile while it is in VMEM
    y_ref[...] = x_ref[...] @ w2


def fwd_update_matmul(x, w, grad, m, *, lr, mu, wd):
    """Fused lazy update + forward matmul for y = x@w'.

    x: [M, K]; w, grad, m: [K, N]. Returns (y, w', grad'=0, m').
    """
    mm, k = x.shape
    _, n = w.shape
    bn = _block(n)
    grid = (n // bn,)
    wspec = pl.BlockSpec((k, bn), lambda j: (0, j))
    return pl.pallas_call(
        functools.partial(_fwd_kernel, lr=lr, mu=mu, wd=wd),
        grid=grid,
        in_specs=[
            pl.BlockSpec((mm, k), lambda j: (0, 0)),
            wspec,
            wspec,
            wspec,
        ],
        out_specs=[
            pl.BlockSpec((mm, bn), lambda j: (0, j)),
            wspec,
            wspec,
            wspec,
        ],
        out_shape=[
            jax.ShapeDtypeStruct((mm, n), x.dtype),
            jax.ShapeDtypeStruct((k, n), w.dtype),
            jax.ShapeDtypeStruct((k, n), grad.dtype),
            jax.ShapeDtypeStruct((k, n), m.dtype),
        ],
        interpret=INTERPRET,
    )(x, w, grad, m)
