"""Pure-jnp oracles for every Pallas kernel in this package.

These define the *semantics* the kernels must match (up to fp tolerance).
pytest sweeps shapes/dtypes with hypothesis and asserts allclose(kernel, ref).
"""

import jax.numpy as jnp


def sgd_ref(theta, grad, lr, wd):
    """Plain SGD with (coupled) weight decay; returns (theta', grad'=0)."""
    g = grad + wd * theta
    return theta - lr * g, jnp.zeros_like(grad)


def sgdm_ref(theta, grad, m, lr, mu, wd):
    """Heavy-ball momentum: m' = mu*m + (g + wd*theta); theta' = theta - lr*m'."""
    g = grad + wd * theta
    m2 = mu * m + g
    return theta - lr * m2, jnp.zeros_like(grad), m2


def adamw_ref(theta, grad, m, v, step, lr, b1, b2, eps, wd):
    """Decoupled-weight-decay Adam (Loshchilov & Hutter).

    step is the 1-based iteration index used for bias correction.
    Returns (theta', grad'=0, m', v').
    """
    theta = theta * (1.0 - lr * wd)
    m2 = b1 * m + (1.0 - b1) * grad
    v2 = b2 * v + (1.0 - b2) * grad * grad
    mhat = m2 / (1.0 - b1**step)
    vhat = v2 / (1.0 - b2**step)
    theta2 = theta - lr * mhat / (jnp.sqrt(vhat) + eps)
    return theta2, jnp.zeros_like(grad), m2, v2


def bwd_matmul_sgd_ref(x, dy, w, lr, wd):
    """Backward-fusion hot spot: matmul backward + in-place SGD update.

    Given the layer y = x @ w and upstream grad dy:
      dx = dy @ w.T          (uses the OLD w — the §B.2 race rule)
      dw = x.T @ dy
      w' = w - lr*(dw + wd*w)
    Returns (dx, w').
    """
    dx = dy @ w.T
    dw = x.T @ dy
    w2 = w - lr * (dw + wd * w)
    return dx, w2


def fwd_update_matmul_ref(x, w, grad, m, lr, mu, wd):
    """Forward-fusion hot spot: lazy SGD-momentum update of w fused with
    the next forward matmul.

      m' = mu*m + (grad + wd*w)
      w' = w - lr*m'
      y  = x @ w'              (forward uses the UPDATED weight)
    Returns (y, w', grad'=0, m').
    """
    g = grad + wd * w
    m2 = mu * m + g
    w2 = w - lr * m2
    y = x @ w2
    return y, w2, jnp.zeros_like(grad), m2


def adagrad_ref(theta, grad, h, lr, eps, wd):
    """Adagrad: h' = h + g²; θ' = θ − lr·g/(√h' + eps)."""
    g = grad + wd * theta
    h2 = h + g * g
    return theta - lr * g / (jnp.sqrt(h2) + eps), jnp.zeros_like(grad), h2


def rmsprop_ref(theta, grad, v, lr, rho, eps, wd):
    """RMSprop: v' = ρv + (1−ρ)g²; θ' = θ − lr·g/(√v' + eps)."""
    g = grad + wd * theta
    v2 = rho * v + (1.0 - rho) * g * g
    return theta - lr * g / (jnp.sqrt(v2) + eps), jnp.zeros_like(grad), v2
