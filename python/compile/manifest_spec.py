"""The artifact manifest: every HLO module the rust runtime can load,
with its entry function and example input shapes. Shared between aot.py
(which lowers them) and the pytest suite (which checks them).

The shapes here must match what rust/src/runtime callers use — HLO
artifacts are shape-specialized.
"""

import functools

import jax.numpy as jnp

from . import model


def f32(*shape):
    return ("f32", tuple(shape))


#: name -> (callable, [input specs]); scalar step inputs are f32[] arrays.
ENTRIES = {
    # fused whole-train-step module (the L2 flagship)
    "mlp_train_step_8x64x32x10": (
        model.mlp_train_step,
        [f32(8, 64), f32(8, 10), f32(64, 32), f32(32, 10)],
    ),
    # fused optimizer updates at the rust transformer's layer shapes
    "adamw_update_64x64": (
        model.adamw_entry,
        [f32(64, 64), f32(64, 64), f32(64, 64), f32(64, 64), f32()],
    ),
    "adamw_update_128x512": (
        model.adamw_entry,
        [f32(128, 512), f32(128, 512), f32(128, 512), f32(128, 512), f32()],
    ),
    "sgdm_update_64x256": (
        model.sgdm_entry,
        [f32(64, 256), f32(64, 256), f32(64, 256)],
    ),
    "adagrad_update_64x256": (
        model.adagrad_entry,
        [f32(64, 256), f32(64, 256), f32(64, 256)],
    ),
    "rmsprop_update_64x256": (
        model.rmsprop_entry,
        [f32(64, 256), f32(64, 256), f32(64, 256)],
    ),
    # schedule-rewrite kernels fused with their adjacent matmuls
    "bwd_matmul_sgd_32x64x128": (
        model.bwd_fused_entry,
        [f32(32, 64), f32(32, 128), f32(64, 128)],
    ),
    "fwd_update_matmul_32x64x128": (
        model.fwd_fused_entry,
        [f32(32, 64), f32(64, 128), f32(64, 128), f32(64, 128)],
    ),
    # transformer FFN block forward
    "ffn_block_64x128": (
        model.ffn_block,
        [f32(64, 128), f32(128), f32(128), f32(128, 512), f32(512),
         f32(512, 128), f32(128)],
    ),
}


def example_args(specs):
    """ShapeDtypeStructs for jax.jit(...).lower(*args)."""
    import jax

    out = []
    for dtype, shape in specs:
        assert dtype == "f32"
        out.append(jax.ShapeDtypeStruct(shape, jnp.float32))
    return out


@functools.lru_cache(None)
def entry_names():
    return sorted(ENTRIES)
