"""AOT lowering: jax -> HLO *text* artifacts + manifest.json.

HLO text (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which the rust `xla`
crate's XLA (xla_extension 0.5.1) rejects; the text parser reassigns ids
and round-trips cleanly. See /opt/xla-example/README.md.

Usage:  python -m compile.aot --out ../artifacts
"""

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from .manifest_spec import ENTRIES, example_args


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True so the
    rust side always unwraps a tuple, even for single outputs)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_entry(name: str) -> tuple[str, int]:
    """Lower one manifest entry; returns (hlo_text, n_outputs)."""
    fn, specs = ENTRIES[name]
    args = example_args(specs)
    lowered = jax.jit(fn).lower(*args)
    n_out = len(jax.eval_shape(fn, *args))
    return to_hlo_text(lowered), n_out


def build(out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"format": 1, "artifacts": []}
    for name in sorted(ENTRIES):
        _, specs = ENTRIES[name]
        text, n_out = lower_entry(name)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        manifest["artifacts"].append(
            {
                "name": name,
                "file": fname,
                "inputs": [list(shape) for _, shape in specs],
                "outputs": n_out,
            }
        )
        print(f"lowered {name}: {len(text)} chars, {n_out} outputs")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    m = build(args.out)
    print(f"wrote {len(m['artifacts'])} artifacts to {args.out}")


if __name__ == "__main__":
    main()
