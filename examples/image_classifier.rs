//! Domain example: image classification (the paper's main workload).
//! Trains the MobileNetV2-style model — the paper's best case — on
//! synthetic images under all three schedules and prints a Fig.-3-style
//! per-stage breakdown plus measured speedups, then sweeps the optimizer
//! (a slice of Fig. 7 measured on this host).
//!
//! Run: cargo run --release --example image_classifier

use optfuse::data::image_batch;
use optfuse::exec::{ExecConfig, Executor};
use optfuse::graph::{Graph, ScheduleKind};
use optfuse::models::{mobilenet_v2_ish, wide_mlp};
use optfuse::optim::{self, Hyper};
use optfuse::train;
use optfuse::util::XorShiftRng;

fn run(
    build: fn(u64) -> Graph,
    kind: ScheduleKind,
    opt: &str,
    batch: usize,
    steps: usize,
) -> train::RunReport {
    let mut ex = Executor::new(
        build(42),
        optim::by_name(opt).unwrap(),
        Hyper { lr: 1e-3, ..Hyper::default() },
        ExecConfig { schedule: kind, threads: 4, race_guard: true, ..Default::default() },
    )
    .unwrap();
    let mut rng = XorShiftRng::new(9);
    train::run(&mut ex, steps, 2, |_| image_batch(batch, 3, 16, 16, 10, &mut rng))
}

fn main() -> anyhow::Result<()> {
    let batch = 32;
    let steps = 10;
    println!("== image classifier: mobilenet_v2_ish, batch {batch} (paper Fig. 3 setting) ==\n");

    println!("-- schedule breakdown (Adam) --");
    let base = run(mobilenet_v2_ish, ScheduleKind::Baseline, "adam", batch, steps);
    println!("{}", train::breakdown_row("baseline", &base));
    for kind in [ScheduleKind::ForwardFusion, ScheduleKind::BackwardFusion] {
        let r = run(mobilenet_v2_ish, kind, "adam", batch, steps);
        println!(
            "{}  speedup {:.3}x",
            train::breakdown_row(kind.label(), &r),
            base.iter_ms() / r.iter_ms()
        );
        assert_eq!(r.losses, base.losses, "training must be unchanged");
    }

    // Measured Fig.-7 slice. On this CPU host fwd/bwd at batch 32 dwarfs
    // the update, so the optimizer-ratio regime of the paper is reached
    // with a parameter-heavy model at small batch (see DESIGN.md §4).
    println!(
        "\n-- optimizer sweep (wide_mlp, batch 2: high optimizer-time ratio, Fig. 7 slice) --"
    );
    for opt in ["sgd", "sgd_momentum", "rmsprop", "adam", "adadelta"] {
        let b = run(wide_mlp, ScheduleKind::Baseline, opt, 2, steps);
        let f = run(wide_mlp, ScheduleKind::BackwardFusion, opt, 2, steps);
        let (_, _, o) = b.breakdown_ms();
        println!(
            "  {opt:<14} opt-stage {o:6.2} ms ({:4.1}% of iter)  ->  BF speedup {:.3}x",
            100.0 * o / b.iter_ms(),
            b.iter_ms() / f.iter_ms()
        );
    }
    println!("\nall schedule loss traces identical ✓");
    Ok(())
}
