//! End-to-end driver (DESIGN.md §5, EXPERIMENTS.md §E2E): train a
//! transformer language model on a synthetic Markov corpus for a few
//! hundred steps under backward-fusion, logging the loss curve and the
//! per-stage breakdown, then verify the final loss matches a baseline-
//! schedule run exactly.
//!
//! The paper's §C.4 trains Transformer-base on WMT En-De; per DESIGN.md §4
//! we substitute a scaled-down decoder-only LM (CPU host) — the schedule
//! mechanics and the equivalence claim are scale-independent.
//!
//! Run: cargo run --release --example train_transformer -- [steps] [dim] [layers]

use optfuse::data::synthetic_corpus;
use optfuse::exec::{ExecConfig, Executor};
use optfuse::graph::ScheduleKind;
use optfuse::models::transformer::{token_batch, transformer_lm};
use optfuse::models::TransformerCfg;
use optfuse::optim::{AdamW, Hyper};
use optfuse::util::XorShiftRng;
use std::io::Write;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let steps: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(300);
    let dim: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(128);
    let layers: usize = args.get(3).and_then(|s| s.parse().ok()).unwrap_or(4);

    let cfg = TransformerCfg {
        vocab: 256,
        dim,
        heads: (dim / 16).max(1),
        layers,
        ff_mult: 4,
        seq: 64,
        tied_head: false,
    };
    let batch = 8;
    let graph = transformer_lm(&cfg, 1234);
    let n_params = graph.store.num_scalars();
    println!(
        "== e2e: decoder-only transformer LM: dim={dim} layers={layers} seq={} vocab={} ({:.2}M params) ==",
        cfg.seq,
        cfg.vocab,
        n_params as f64 / 1e6
    );
    println!("schedule: backward-fusion, AdamW, batch {batch}, {steps} steps\n");

    let corpus = synthetic_corpus(1 << 16, cfg.vocab, 99);
    let uniform_floor = (cfg.vocab as f32).ln();

    let mut ex = Executor::new(
        graph,
        Box::new(AdamW),
        Hyper { lr: 3e-4, weight_decay: 1e-2, ..Hyper::default() },
        ExecConfig {
            schedule: ScheduleKind::BackwardFusion,
            threads: 4,
            race_guard: true,
            ..Default::default()
        },
    )?;

    let mut rng = XorShiftRng::new(5);
    let mut csv = String::from("step,loss\n");
    let t0 = std::time::Instant::now();
    let mut first = f32::NAN;
    let mut last = f32::NAN;
    for step in 1..=steps {
        let b = token_batch(&cfg, batch, &corpus, &mut rng);
        let s = ex.train_step(&b);
        if step == 1 {
            first = s.loss;
        }
        last = s.loss;
        csv.push_str(&format!("{step},{}\n", s.loss));
        if step % 25 == 0 || step == 1 {
            println!(
                "step {step:>4}  loss {:.4}  (uniform floor would be {:.4})  {:.0} tok/s",
                s.loss,
                uniform_floor,
                (batch * cfg.seq) as f64 / s.total().as_secs_f64()
            );
        }
    }
    let wall = t0.elapsed();
    let path = "train_transformer_loss.csv";
    std::fs::File::create(path)?.write_all(csv.as_bytes())?;
    println!(
        "\ntrained {steps} steps in {:.1}s  |  loss {first:.4} -> {last:.4}  |  curve -> {path}",
        wall.as_secs_f64()
    );
    assert!(
        last < first && last < uniform_floor,
        "the model must actually learn the corpus structure"
    );

    // equivalence spot-check: 10 baseline steps from the same init must
    // reproduce the first 10 BF losses bit-for-bit
    let mut base = Executor::new(
        transformer_lm(&cfg, 1234),
        Box::new(AdamW),
        Hyper { lr: 3e-4, weight_decay: 1e-2, ..Hyper::default() },
        ExecConfig { schedule: ScheduleKind::Baseline, ..Default::default() },
    )?;
    let mut rng2 = XorShiftRng::new(5);
    for step in 1..=10 {
        let b = token_batch(&cfg, batch, &corpus, &mut rng2);
        let l = base.train_step(&b).loss;
        let bf_l: f32 = csv
            .lines()
            .nth(step)
            .and_then(|l| l.split(',').nth(1))
            .and_then(|v| v.parse().ok())
            .unwrap();
        assert_eq!(l, bf_l, "baseline and BF must agree at step {step}");
    }
    println!("baseline vs backward-fusion: first 10 losses bit-identical ✓");
    Ok(())
}
