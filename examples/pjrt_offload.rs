//! Three-layer integration demo: the rust coordinator driving AOT
//! JAX/Pallas artifacts through PJRT — python never runs here.
//!
//! 1. loads `artifacts/manifest.json` (produced once by `make artifacts`),
//! 2. runs the *fused whole-train-step* module (L2 jax + L1 Pallas SGD
//!    kernel compiled into one XLA executable) in a training loop,
//! 3. cross-checks the fused AdamW Pallas kernel against the rust-native
//!    optimizer — two independent implementations, same numbers.
//!
//! Run: make artifacts && cargo run --release --example pjrt_offload

use optfuse::graph::ParamData;
use optfuse::optim::{AdamW, Hyper, Optimizer};
use optfuse::runtime::{default_artifacts_dir, Runtime};
use optfuse::tensor::Tensor;
use optfuse::util::XorShiftRng;

fn main() -> anyhow::Result<()> {
    if !Runtime::available() {
        println!(
            "built without PJRT support — add the `xla` dependency to Cargo.toml and build \
             with `--features pjrt` to run this demo"
        );
        return Ok(());
    }
    let rt = Runtime::load(default_artifacts_dir())?;
    println!("PJRT platform: {} | artifacts: {:?}\n", rt.platform(), rt.artifact_names());

    // ---- compiled train loop ----
    let mut rng = XorShiftRng::new(3);
    let x = Tensor::randn(&[8, 64], 1.0, &mut rng);
    let y = Tensor::randn(&[8, 10], 1.0, &mut rng);
    let mut w1 = Tensor::randn(&[64, 32], 0.2, &mut rng);
    let mut w2 = Tensor::randn(&[32, 10], 0.2, &mut rng);
    println!("-- compiled MLP train step (fwd+bwd+Pallas-SGD as ONE XLA module) --");
    let t0 = std::time::Instant::now();
    let mut first = f32::NAN;
    let mut last = f32::NAN;
    for step in 1..=50 {
        let out = rt.execute("mlp_train_step_8x64x32x10", &[x.clone(), y.clone(), w1, w2])?;
        let loss = out[0].data()[0];
        if step == 1 {
            first = loss;
        }
        last = loss;
        w1 = out[1].clone();
        w2 = out[2].clone();
        if step % 10 == 0 {
            println!("  step {step:>3}  loss {loss:.5}");
        }
    }
    println!(
        "  50 steps in {:.1} ms  |  loss {first:.4} -> {last:.4} (must decrease: {})\n",
        t0.elapsed().as_secs_f64() * 1e3,
        last < first
    );
    assert!(last < first);

    // ---- cross-implementation check: Pallas AdamW == rust AdamW ----
    println!("-- fused AdamW: Pallas artifact vs rust-native optimizer --");
    let theta = Tensor::randn(&[64, 64], 1.0, &mut rng);
    let grad = Tensor::randn(&[64, 64], 1.0, &mut rng);
    let out = rt.execute(
        "adamw_update_64x64",
        &[
            theta.clone(),
            grad.clone(),
            Tensor::zeros(&[64, 64]),
            Tensor::zeros(&[64, 64]),
            Tensor::from_vec(&[], vec![1.0]),
        ],
    )?;
    let mut pd = ParamData {
        name: "p".into(),
        value: theta,
        grad,
        state: vec![Tensor::zeros(&[64, 64]), Tensor::zeros(&[64, 64])],
    };
    AdamW.update(
        1,
        &mut pd,
        &Hyper { lr: 1e-3, weight_decay: 1e-2, ..Hyper::default() },
        1.0,
    );
    let diff = out[0].max_abs_diff(&pd.value);
    println!("  max |θ'_pallas − θ'_rust| = {diff:.2e}  (tolerance 1e-5)");
    assert!(diff < 1e-5);
    println!("\nthree-layer stack verified: rust L3 ⇄ PJRT ⇄ jax L2 ⇄ pallas L1 ✓");
    Ok(())
}
