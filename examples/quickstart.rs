//! Quickstart: the paper's contribution in 60 lines.
//!
//! Builds a small MLP, trains it under the three schedules (baseline,
//! forward-fusion, backward-fusion), and shows that (a) the losses are
//! bit-identical — the schedules do not change the math — while (b) the
//! per-stage time breakdown shifts exactly as the paper's Fig. 3 says:
//! the standalone optimizer stage disappears into forward (FF) or
//! overlaps backward (BF).
//!
//! Run: cargo run --release --example quickstart

use optfuse::data::image_batch;
use optfuse::exec::{ExecConfig, Executor};
use optfuse::graph::ScheduleKind;
use optfuse::models::mlp;
use optfuse::optim::{Adam, Hyper};
use optfuse::train;
use optfuse::util::XorShiftRng;

fn main() -> anyhow::Result<()> {
    let steps = 30;
    let batch = 64;
    println!("== optfuse quickstart: 3-layer MLP, Adam, batch {batch} ==\n");

    let mut results = Vec::new();
    for kind in ScheduleKind::ALL {
        let mut ex = Executor::new(
            mlp(42), // same seed -> identical init for all schedules
            Box::new(Adam),
            Hyper { lr: 1e-3, ..Hyper::default() },
            ExecConfig { schedule: kind, threads: 4, race_guard: true, ..Default::default() },
        )?;
        let mut rng = XorShiftRng::new(7); // same data stream too
        let report = train::run(&mut ex, steps, 3, |_| {
            image_batch(batch, 3, 16, 16, 10, &mut rng)
        });
        println!("{}", train::breakdown_row(kind.label(), &report));
        results.push((kind, report));
    }

    println!();
    let base_losses = &results[0].1.losses;
    for (kind, r) in &results[1..] {
        assert_eq!(
            &r.losses, base_losses,
            "{kind:?} loss trace must match baseline exactly"
        );
        println!(
            "{:<16} losses identical to baseline ✓   speedup {:.3}x",
            kind.label(),
            results[0].1.iter_ms() / r.iter_ms()
        );
    }
    println!(
        "\nfinal loss {:.4} (started {:.4}) — schedules change *when* updates run, never *what* they compute",
        base_losses.last().unwrap(),
        base_losses.first().unwrap()
    );
    Ok(())
}
